//! Randomized k-d-tree ensemble, after FLANN (Muja & Lowe 2014), the index
//! the paper uses for small word sizes (§3.5, Fig 1a "k-d tree: 4 trees,
//! 32 checks").
//!
//! Each tree splits on a dimension drawn at random from the few highest-
//! variance dimensions, at the mean value; queries run best-bin-first with a
//! shared priority queue and stop after inspecting `checks` candidate
//! points. Online inserts append to the leaf the point lands in; deletes
//! tombstone. The forest is rebuilt from scratch every `rebuild_every`
//! inserts — the paper rebuilds every N insertions "to ensure it does not
//! become imbalanced".

use super::{normalized, unit_dist_sq_to_cosine, AnnIndex};
use crate::tensor::matrix::dist_sq;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

const LEAF_SIZE: usize = 16;
/// How many top-variance dims the random split dimension is drawn from
/// (FLANN uses 5).
const RAND_DIM_CANDIDATES: usize = 5;

#[derive(Debug, Clone)]
enum Node {
    Split { dim: usize, threshold: f32, left: usize, right: usize },
    Leaf { ids: Vec<usize> },
}

#[derive(Debug, Clone)]
struct Tree {
    nodes: Vec<Node>,
    root: usize,
}

/// Min-heap entry for best-bin-first traversal: (lower-bound distance, tree, node).
struct QueueEntry {
    bound: f32,
    tree: usize,
    node: usize,
}

impl PartialEq for QueueEntry {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for QueueEntry {}
impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want smallest bound first.
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

/// FLANN-style randomized k-d forest over normalized memory rows.
pub struct KdForest {
    dim: usize,
    n_trees: usize,
    /// Best-bin-first candidate budget per query.
    pub checks: usize,
    rebuild_every: usize,
    inserts_since_rebuild: usize,
    /// Flat normalized row storage.
    data: Vec<f32>,
    present: Vec<bool>,
    count: usize,
    trees: Vec<Tree>,
    rng: Rng,
    /// Query-visited stamps (avoids a HashSet per query).
    stamp: Vec<u32>,
    stamp_now: u32,
    /// Full forest rebuilds performed (initial build included); lets tests
    /// assert the incremental update path stays incremental.
    rebuilds: usize,
}

impl KdForest {
    /// Paper configuration: 4 trees, 32 checks, rebuild every N inserts.
    pub fn with_defaults(n: usize, dim: usize, seed: u64) -> KdForest {
        KdForest::new(n, dim, 4, 32, n.max(64), seed)
    }

    pub fn new(
        n: usize,
        dim: usize,
        n_trees: usize,
        checks: usize,
        rebuild_every: usize,
        seed: u64,
    ) -> KdForest {
        KdForest {
            dim,
            n_trees,
            checks,
            rebuild_every,
            inserts_since_rebuild: 0,
            data: vec![0.0; n * dim],
            present: vec![false; n],
            count: 0,
            trees: Vec::new(),
            rng: Rng::new(seed),
            stamp: vec![0; n],
            stamp_now: 0,
            rebuilds: 0,
        }
    }

    #[inline]
    fn point(&self, id: usize) -> &[f32] {
        &self.data[id * self.dim..(id + 1) * self.dim]
    }

    /// Choose a split dimension: random among the RAND_DIM_CANDIDATES
    /// highest-variance dims of the ids (FLANN's randomization).
    fn choose_split(&mut self, ids: &[usize]) -> (usize, f32) {
        let dim = self.dim;
        let mut mean = vec![0.0f32; dim];
        for &id in ids {
            for (m, x) in mean.iter_mut().zip(self.point(id)) {
                *m += x;
            }
        }
        let inv = 1.0 / ids.len() as f32;
        mean.iter_mut().for_each(|m| *m *= inv);
        let mut var = vec![0.0f32; dim];
        for &id in ids {
            for ((v, x), m) in var.iter_mut().zip(self.point(id)).zip(&mean) {
                let d = x - m;
                *v += d * d;
            }
        }
        let mut order: Vec<usize> = (0..dim).collect();
        order.sort_unstable_by(|&a, &b| var[b].partial_cmp(&var[a]).unwrap());
        let pick = order[self.rng.below(RAND_DIM_CANDIDATES.min(dim))];
        (pick, mean[pick])
    }

    fn build_subtree(&mut self, nodes: &mut Vec<Node>, mut ids: Vec<usize>) -> usize {
        if ids.len() <= LEAF_SIZE {
            nodes.push(Node::Leaf { ids });
            return nodes.len() - 1;
        }
        let (dim, threshold) = self.choose_split(&ids);
        let (mut l, mut r) = (Vec::new(), Vec::new());
        for id in ids.drain(..) {
            if self.point(id)[dim] < threshold {
                l.push(id);
            } else {
                r.push(id);
            }
        }
        // Degenerate split (all equal along dim): make a leaf.
        if l.is_empty() || r.is_empty() {
            let mut all = l;
            all.extend(r);
            nodes.push(Node::Leaf { ids: all });
            return nodes.len() - 1;
        }
        let left = self.build_subtree(nodes, l);
        let right = self.build_subtree(nodes, r);
        nodes.push(Node::Split { dim, threshold, left, right });
        nodes.len() - 1
    }

    fn build_tree(&mut self) -> Tree {
        let ids: Vec<usize> =
            (0..self.present.len()).filter(|&i| self.present[i]).collect();
        let mut nodes = Vec::with_capacity(2 * ids.len() / LEAF_SIZE + 4);
        let root = if ids.is_empty() {
            nodes.push(Node::Leaf { ids: Vec::new() });
            0
        } else {
            self.build_subtree(&mut nodes, ids)
        };
        Tree { nodes, root }
    }

    fn rebuild_all(&mut self) {
        self.trees = (0..self.n_trees).map(|_| self.build_tree()).collect();
        self.inserts_since_rebuild = 0;
        self.rebuilds += 1;
        crate::util::metrics::ANN_FULL_REBUILDS.inc();
    }

    /// Descend to the leaf for `v` in tree `t`, returning the node index.
    fn find_leaf(&self, t: usize, v: &[f32]) -> usize {
        let tree = &self.trees[t];
        let mut node = tree.root;
        loop {
            match &tree.nodes[node] {
                Node::Leaf { .. } => return node,
                Node::Split { dim, threshold, left, right } => {
                    node = if v[*dim] < *threshold { *left } else { *right };
                }
            }
        }
    }

    fn next_stamp(&mut self) -> u32 {
        self.stamp_now = self.stamp_now.wrapping_add(1);
        if self.stamp_now == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.stamp_now = 1;
        }
        self.stamp_now
    }
}

impl AnnIndex for KdForest {
    fn len(&self) -> usize {
        self.count
    }

    fn insert(&mut self, id: usize, v: &[f32]) {
        assert_eq!(v.len(), self.dim);
        if id >= self.present.len() {
            self.present.resize(id + 1, false);
            self.data.resize((id + 1) * self.dim, 0.0);
            self.stamp.resize(id + 1, 0);
        }
        let nv = normalized(v);
        self.data[id * self.dim..(id + 1) * self.dim].copy_from_slice(&nv);
        if !self.present[id] {
            self.present[id] = true;
            self.count += 1;
        }
        if self.trees.is_empty() {
            self.rebuild_all();
            return;
        }
        // Online insert: append to the leaf this point lands in, per tree.
        for t in 0..self.trees.len() {
            let leaf = self.find_leaf(t, &nv);
            if let Node::Leaf { ids } = &mut self.trees[t].nodes[leaf] {
                if !ids.contains(&id) {
                    ids.push(id);
                }
            }
        }
        self.inserts_since_rebuild += 1;
        if self.inserts_since_rebuild >= self.rebuild_every {
            self.rebuild_all();
        }
    }

    fn remove(&mut self, id: usize) {
        if id < self.present.len() && self.present[id] {
            self.present[id] = false;
            self.count -= 1;
            // Lazy delete: queries filter on `present`; the id physically
            // leaves the leaves at the next rebuild. Removing it from its
            // current leaves here would require a find in each tree, which
            // `update` would immediately undo.
        }
    }

    fn update(&mut self, id: usize, v: &[f32]) {
        // A moved point must leave its old leaves, otherwise stale copies
        // shadow the new position. Tombstone then re-insert: the tombstoned
        // copy is filtered by the `present` check until rebuild, and insert
        // sets `present` again with the new coordinates.
        // Physically drop the old copy from leaves first.
        let nv_old_present = id < self.present.len() && self.present[id];
        if nv_old_present {
            let old = self.point(id).to_vec();
            for t in 0..self.trees.len() {
                let leaf = self.find_leaf(t, &old);
                if let Node::Leaf { ids } = &mut self.trees[t].nodes[leaf] {
                    ids.retain(|&x| x != id);
                }
            }
            self.present[id] = false;
            self.count -= 1;
        }
        self.insert(id, v);
    }

    fn query(&mut self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        if self.trees.is_empty() {
            self.rebuild_all();
        }
        let qn = normalized(q);
        let stamp = self.next_stamp();
        let mut heap: BinaryHeap<QueueEntry> = BinaryHeap::new();
        for (t, tree) in self.trees.iter().enumerate() {
            heap.push(QueueEntry { bound: 0.0, tree: t, node: tree.root });
        }
        let mut best: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
        let mut checked = 0usize;
        while let Some(QueueEntry { bound, tree, node }) = heap.pop() {
            if checked >= self.checks && best.len() >= k {
                break;
            }
            // Prune cells further than the current kth distance.
            if best.len() >= k && bound > best.last().unwrap().1 {
                continue;
            }
            let mut cur = node;
            loop {
                match &self.trees[tree].nodes[cur] {
                    Node::Split { dim, threshold, left, right } => {
                        let diff = qn[*dim] - *threshold;
                        let (near, far) =
                            if diff < 0.0 { (*left, *right) } else { (*right, *left) };
                        let far_bound = bound + diff * diff;
                        heap.push(QueueEntry { bound: far_bound, tree, node: far });
                        cur = near;
                    }
                    Node::Leaf { ids } => {
                        for &id in ids {
                            if !self.present[id] || self.stamp[id] == stamp {
                                continue;
                            }
                            self.stamp[id] = stamp;
                            checked += 1;
                            let d2 = dist_sq(&qn, self.point(id));
                            if best.len() < k || d2 < best.last().unwrap().1 {
                                let pos = best.partition_point(|&(_, bd)| bd <= d2);
                                best.insert(pos, (id, d2));
                                if best.len() > k {
                                    best.pop();
                                }
                            }
                        }
                        break;
                    }
                }
            }
        }
        crate::util::metrics::ANN_QUERIES.inc();
        crate::util::metrics::ANN_CANDIDATES.add(checked as u64);
        best.into_iter()
            .map(|(id, d2)| (id, unit_dist_sq_to_cosine(d2)))
            .collect()
    }

    fn rebuild(&mut self) {
        self.rebuild_all();
    }

    fn full_rebuilds(&self) -> usize {
        self.rebuilds
    }

    fn heap_bytes(&self) -> usize {
        let tree_bytes: usize = self
            .trees
            .iter()
            .map(|t| {
                t.nodes
                    .iter()
                    .map(|n| match n {
                        Node::Leaf { ids } => 48 + ids.capacity() * 8,
                        Node::Split { .. } => 48,
                    })
                    .sum::<usize>()
            })
            .sum();
        self.data.capacity() * 4 + self.present.capacity() + self.stamp.capacity() * 4 + tree_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::LinearIndex;

    fn random_points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect()
    }

    /// recall@k of the forest against exact KNN.
    fn recall(forest: &mut KdForest, exact: &mut LinearIndex, queries: &[Vec<f32>], k: usize) -> f64 {
        let mut hit = 0usize;
        let mut total = 0usize;
        for q in queries {
            let approx: std::collections::HashSet<usize> =
                forest.query(q, k).into_iter().map(|(i, _)| i).collect();
            for (i, _) in exact.query(q, k) {
                total += 1;
                if approx.contains(&i) {
                    hit += 1;
                }
            }
        }
        hit as f64 / total as f64
    }

    #[test]
    fn forest_high_recall_on_near_queries() {
        // The SAM regime: queries are learned to point at stored memories,
        // so recall matters for queries *near* stored points (uniformly
        // random queries in high dim are the known worst case for k-d
        // trees and not the workload).
        let dim = 16;
        let n = 512;
        let pts = random_points(n, dim, 11);
        let mut forest = KdForest::new(n, dim, 4, 128, 10 * n, 1);
        let mut exact = LinearIndex::new(n, dim);
        for (i, p) in pts.iter().enumerate() {
            forest.insert(i, p);
            exact.insert(i, p);
        }
        forest.rebuild();
        let mut qrng = Rng::new(99);
        let queries: Vec<Vec<f32>> = (0..32)
            .map(|qi| {
                pts[(qi * 13) % n]
                    .iter()
                    .map(|x| x + 0.1 * qrng.normal())
                    .collect()
            })
            .collect();
        let r = recall(&mut forest, &mut exact, &queries, 4);
        assert!(r > 0.75, "recall@4 = {r}");
    }

    #[test]
    fn online_inserts_are_queryable() {
        let dim = 8;
        let mut forest = KdForest::new(64, dim, 4, 32, 1_000_000, 2);
        let pts = random_points(64, dim, 3);
        for (i, p) in pts.iter().enumerate() {
            forest.insert(i, p);
        }
        // Insert a point identical to the query — must be found without rebuild.
        let q = vec![0.5; 8];
        forest.insert(63, &q);
        let r = forest.query(&q, 1);
        assert_eq!(r[0].0, 63);
        assert!((r[0].1 - 1.0).abs() < 1e-4);
    }

    #[test]
    fn update_moves_point() {
        let dim = 8;
        let mut forest = KdForest::new(16, dim, 4, 64, 1_000_000, 4);
        let pts = random_points(16, dim, 5);
        for (i, p) in pts.iter().enumerate() {
            forest.insert(i, p);
        }
        let target = vec![9.0, -9.0, 9.0, -9.0, 9.0, -9.0, 9.0, -9.0];
        forest.update(3, &target);
        let r = forest.query(&target, 1);
        assert_eq!(r[0].0, 3);
        // And the old location no longer matches id 3 best.
        let r_old = forest.query(&pts[3], 2);
        assert!((r_old[0].1 - 1.0).abs() > 1e-3 || r_old[0].0 != 3);
    }

    #[test]
    fn remove_hides_point() {
        let dim = 4;
        let mut forest = KdForest::new(8, dim, 2, 32, 1_000_000, 6);
        let pts = random_points(8, dim, 7);
        for (i, p) in pts.iter().enumerate() {
            forest.insert(i, p);
        }
        let r1 = forest.query(&pts[2], 1);
        assert_eq!(r1[0].0, 2);
        forest.remove(2);
        let r2 = forest.query(&pts[2], 1);
        assert_ne!(r2[0].0, 2);
        assert_eq!(forest.len(), 7);
    }

    #[test]
    fn rebuild_preserves_contents() {
        let dim = 8;
        let n = 128;
        let pts = random_points(n, dim, 8);
        // rebuild_every = 32 -> several automatic rebuilds during inserts
        let mut forest = KdForest::new(n, dim, 3, 48, 32, 9);
        for (i, p) in pts.iter().enumerate() {
            forest.insert(i, p);
        }
        assert_eq!(forest.len(), n);
        for i in (0..n).step_by(17) {
            let r = forest.query(&pts[i], 1);
            assert_eq!(r[0].0, i, "self-query failed for {i}");
        }
    }
}
