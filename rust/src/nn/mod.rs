//! Neural building blocks with hand-derived backward passes.
pub mod act;
pub mod linear;
pub mod loss;
pub mod lstm;
pub mod param;
