//! Fully-connected layer with manual backward and an explicit activation
//! cache stack (supports arbitrarily long BPTT: one push per forward call,
//! one pop per backward call).
//!
//! Hot-path upgrades over a naive per-sample implementation:
//!
//! * **Batched entries** — the batched trainer runs the projection itself
//!   (lane-fused `gemv_many` across B episodes) and enters through
//!   [`Linear::note_forward`]/[`Linear::note_backward`], which carry only
//!   the cache/deferred-gradient bookkeeping of the `*_into` pair; the
//!   serving tick coalesces sessions with the forward-only
//!   [`Linear::infer_batch`]. There is no separate training GEMM path.
//! * **Deferred weight gradients** — the per-step backward no longer does a
//!   rank-1 `outer_acc` per call; it queues (dy, x) pairs and folds the
//!   whole episode's weight gradient in as one `dW += dYᵀ X` GEMM when the
//!   cache stack empties (or on [`Linear::clear_cache`]). Same flops, one
//!   cache-friendly pass, and a single deterministic summation order shared
//!   by the serial and data-parallel trainers.
//! * **Zero-allocation steps** — [`Linear::forward_into`]/
//!   [`Linear::backward_into`] write into caller-reused buffers and draw
//!   cache/tape storage from a layer-private [`Workspace`], recycled as the
//!   episode backpropagates. The allocating [`Linear::forward`]/
//!   [`Linear::backward`] wrappers remain for cold callers and tests.

use super::param::{HasParams, Param};
use crate::tensor::matrix::{axpy, col_sum_acc, dot, gemm_nt, gemm_tn, Matrix};
use crate::tensor::workspace::Workspace;
use crate::util::rng::Rng;

/// y = W x + b.
pub struct Linear {
    pub w: Param, // out × in
    pub b: Param, // 1 × out
    /// Cached inputs, one per un-backpropagated step forward call.
    cache_x: Vec<Vec<f32>>,
    /// (dy, x) pairs awaiting the episode-level GEMM gradient flush.
    pending: Vec<(Vec<f32>, Vec<f32>)>,
    /// Layer-private buffer pool (see [`crate::tensor::workspace`]).
    ws: Workspace,
}

impl Linear {
    pub fn new(name: &str, in_dim: usize, out_dim: usize, rng: &mut Rng) -> Linear {
        Linear {
            w: Param::fan_in(&format!("{name}.w"), out_dim, in_dim, in_dim, rng),
            b: Param::zeros(&format!("{name}.b"), 1, out_dim),
            cache_x: Vec::new(),
            pending: Vec::new(),
            ws: Workspace::new(),
        }
    }

    pub fn in_dim(&self) -> usize {
        self.w.w.cols
    }

    pub fn out_dim(&self) -> usize {
        self.w.w.rows
    }

    /// Forward one vector into a caller-reused output buffer; caches `x`
    /// (pooled copy) for the matching backward.
    pub fn forward_into(&mut self, x: &[f32], y: &mut Vec<f32>) {
        assert_eq!(x.len(), self.in_dim());
        y.clear();
        y.extend_from_slice(&self.b.w.data);
        for (i, yi) in y.iter_mut().enumerate() {
            *yi += dot(self.w.w.row(i), x);
        }
        let xb = self.ws.take_f32_copy(x);
        self.cache_x.push(xb);
    }

    /// Forward one vector; caches `x` for the matching backward.
    /// Allocating wrapper over [`Linear::forward_into`].
    pub fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        let mut y = Vec::new();
        self.forward_into(x, &mut y);
        y
    }

    /// Forward-only apply against shared read-only weights: no activation
    /// cache, no gradient state. Same float-op order as
    /// [`Linear::forward_into`] (bias copy, then one [`dot`] per row), so
    /// infer outputs are bit-identical to train-mode forwards.
    pub fn infer_into(&self, x: &[f32], y: &mut Vec<f32>) {
        assert_eq!(x.len(), self.in_dim());
        y.clear();
        y.extend_from_slice(&self.b.w.data);
        for (i, yi) in y.iter_mut().enumerate() {
            *yi += dot(self.w.w.row(i), x);
        }
    }

    /// Forward-only batched apply: Y = X Wᵀ + b in one GEMM, no cache.
    /// `y` must be pre-sized to x.rows × out_dim (its contents are
    /// overwritten). The serving tick uses this to coalesce many sessions'
    /// projections into a single [`gemm_nt`].
    pub fn infer_batch(&self, x: &Matrix, y: &mut Matrix) {
        assert_eq!(x.cols, self.in_dim());
        assert_eq!(y.rows, x.rows);
        assert_eq!(y.cols, self.out_dim());
        for t in 0..y.rows {
            y.row_mut(t).copy_from_slice(&self.b.w.data);
        }
        gemm_nt(y, x, &self.w.w);
    }

    /// Heap bytes of the weight matrices (value + optimizer slots).
    pub fn params_heap_bytes(&self) -> usize {
        self.w.heap_bytes() + self.b.heap_bytes()
    }

    /// Backward the most recent un-backpropagated forward, writing dL/dx
    /// into a caller-reused buffer. Weight gradients are queued and folded
    /// in by one GEMM when the last cached step has been backpropagated
    /// (see module docs).
    pub fn backward_into(&mut self, dy: &[f32], dx: &mut Vec<f32>) {
        assert_eq!(dy.len(), self.out_dim());
        let x = self.cache_x.pop().expect("backward without forward");
        dx.clear();
        dx.resize(x.len(), 0.0);
        for (i, &dyi) in dy.iter().enumerate() {
            if dyi != 0.0 {
                axpy(dx, dyi, self.w.w.row(i));
            }
        }
        let dyb = self.ws.take_f32_copy(dy);
        self.pending.push((dyb, x));
        if self.cache_x.is_empty() {
            self.flush_grads();
        }
    }

    /// Allocating wrapper over [`Linear::backward_into`].
    pub fn backward(&mut self, dy: &[f32]) -> Vec<f32> {
        let mut dx = Vec::new();
        self.backward_into(dy, &mut dx);
        dx
    }

    /// Batched-training forward bookkeeping: the caller computed this
    /// lane's y itself (bias row + lane-fused `gemv_many`, which carries
    /// [`Linear::forward_into`]'s bits exactly); cache `x` for the
    /// matching [`Linear::note_backward`]. This is `forward_into` minus
    /// the projection.
    pub fn note_forward(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.in_dim());
        let xb = self.ws.take_f32_copy(x);
        self.cache_x.push(xb);
    }

    /// Batched-training backward bookkeeping: the caller swept dX = dY·W
    /// itself (lane-fused `gemm_rowsweep`, the serial axpy sweep's bits);
    /// pop the cached x, queue (dy, x) for the episode-level GEMM flush and
    /// flush when the cache empties. This is [`Linear::backward_into`]
    /// minus the dx sweep.
    pub fn note_backward(&mut self, dy: &[f32]) {
        assert_eq!(dy.len(), self.out_dim());
        let x = self.cache_x.pop().expect("backward without forward");
        let dyb = self.ws.take_f32_copy(dy);
        self.pending.push((dyb, x));
        if self.cache_x.is_empty() {
            self.flush_grads();
        }
    }

    /// Fold all queued per-step weight gradients in as one GEMM:
    /// dW += dYᵀ X, db += colsum(dY).
    fn flush_grads(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let t = self.pending.len();
        let mut dy = self.ws.take_matrix(t, self.out_dim());
        let mut x = self.ws.take_matrix(t, self.in_dim());
        let mut pending = std::mem::take(&mut self.pending);
        for (r, (dyr, xr)) in pending.drain(..).enumerate() {
            dy.row_mut(r).copy_from_slice(&dyr);
            x.row_mut(r).copy_from_slice(&xr);
            self.ws.recycle_f32(dyr);
            self.ws.recycle_f32(xr);
        }
        self.pending = pending;
        gemm_tn(&mut self.w.g, &dy, &x);
        col_sum_acc(&mut self.b.g.data, &dy);
        self.ws.recycle_matrix(dy);
        self.ws.recycle_matrix(x);
    }

    /// Drop any cached activations (episode reset). A partially
    /// backpropagated episode's queued weight gradients are flushed first
    /// so truncated BPTT keeps its gradients.
    pub fn clear_cache(&mut self) {
        self.flush_grads();
        while let Some(x) = self.cache_x.pop() {
            self.ws.recycle_f32(x);
        }
    }

    pub fn cache_bytes(&self) -> usize {
        self.cache_x.iter().map(|x| x.capacity() * 4 + 24).sum::<usize>()
            + self
                .pending
                .iter()
                .map(|(d, x)| (d.capacity() + x.capacity()) * 4 + 48)
                .sum::<usize>()
    }
}

impl HasParams for Linear {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.w);
        f(&mut self.b);
    }
}

/// Stateless matrix helper for gradient-check tests: y = Wx+b as pure fn.
pub fn linear_apply(w: &Matrix, b: &[f32], x: &[f32]) -> Vec<f32> {
    let mut y = b.to_vec();
    for (i, yi) in y.iter_mut().enumerate() {
        *yi += dot(w.row(i), x);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_matches_manual() {
        let mut rng = Rng::new(1);
        let mut lin = Linear::new("t", 3, 2, &mut rng);
        lin.w.w.data = vec![1., 2., 3., 4., 5., 6.];
        lin.b.w.data = vec![0.5, -0.5];
        let y = lin.forward(&[1., 1., 1.]);
        assert_eq!(y, vec![6.5, 14.5]);
    }

    #[test]
    fn gradients_match_finite_difference() {
        let mut rng = Rng::new(2);
        let mut lin = Linear::new("t", 4, 3, &mut rng);
        let x: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
        let dy = vec![0.3, -0.7, 0.2];
        // loss = dy . y (linear probe)
        let loss = |lin: &mut Linear, x: &[f32]| -> f32 {
            let y = lin.forward(x);
            lin.cache_x.pop();
            y.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        lin.forward(&x);
        let dx = lin.backward(&dy);
        let eps = 1e-2;
        // check dW
        for k in 0..lin.w.w.data.len() {
            let orig = lin.w.w.data[k];
            lin.w.w.data[k] = orig + eps;
            let lp = loss(&mut lin, &x);
            lin.w.w.data[k] = orig - eps;
            let lm = loss(&mut lin, &x);
            lin.w.w.data[k] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - lin.w.g.data[k]).abs() < 1e-3, "W[{k}]");
        }
        // check dx
        for k in 0..x.len() {
            let mut xp = x.clone();
            xp[k] += eps;
            let lp = loss(&mut lin, &xp);
            xp[k] -= 2.0 * eps;
            let lm = loss(&mut lin, &xp);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - dx[k]).abs() < 1e-3, "x[{k}]");
        }
    }

    #[test]
    fn cache_stack_lifo_with_deferred_flush() {
        let mut rng = Rng::new(3);
        let mut lin = Linear::new("t", 2, 2, &mut rng);
        lin.forward(&[1.0, 0.0]);
        lin.forward(&[0.0, 1.0]);
        // backward for second call first (LIFO); the weight gradient is
        // deferred until the stack empties, then flushed as one GEMM.
        lin.backward(&[1.0, 0.0]);
        assert_eq!(lin.w.g.get(0, 1), 0.0, "grads deferred until stack empty");
        lin.backward(&[1.0, 0.0]);
        assert_eq!(lin.w.g.get(0, 1), 1.0); // x2 = e2
        assert_eq!(lin.w.g.get(0, 0), 1.0); // x1 = e1
        assert_eq!(lin.b.g.data, vec![2.0, 0.0]);
        assert_eq!(lin.cache_bytes(), 0);
    }

    #[test]
    fn clear_cache_flushes_partial_backward() {
        let mut rng = Rng::new(4);
        let mut lin = Linear::new("t", 2, 2, &mut rng);
        lin.forward(&[1.0, 0.0]);
        lin.forward(&[0.0, 1.0]);
        lin.backward(&[1.0, 0.0]); // truncated BPTT: only one step back
        lin.clear_cache();
        assert_eq!(lin.w.g.get(0, 1), 1.0, "truncated grads must survive reset");
        assert_eq!(lin.cache_bytes(), 0);
    }

    #[test]
    fn into_variants_match_wrappers() {
        let mut r1 = Rng::new(6);
        let mut r2 = Rng::new(6);
        let mut a = Linear::new("a", 3, 2, &mut r1);
        let mut b = Linear::new("b", 3, 2, &mut r2);
        let mut y = Vec::new();
        let mut dx = Vec::new();
        for _ in 0..3 {
            a.forward_into(&[0.5, -1.0, 2.0], &mut y);
            let yb = b.forward(&[0.5, -1.0, 2.0]);
            assert_eq!(y, yb);
            a.backward_into(&[1.0, -0.5], &mut dx);
            let dxb = b.backward(&[1.0, -0.5]);
            assert_eq!(dx, dxb);
        }
        for (ga, gb) in a.w.g.data.iter().zip(&b.w.g.data) {
            assert_eq!(ga.to_bits(), gb.to_bits());
        }
    }

    #[test]
    fn infer_into_matches_forward_bitwise() {
        let mut rng = Rng::new(7);
        let mut lin = Linear::new("t", 4, 3, &mut rng);
        let x = [0.5f32, -1.0, 2.0, 0.25];
        let mut yi = Vec::new();
        lin.infer_into(&x, &mut yi);
        let yf = lin.forward(&x);
        lin.clear_cache();
        for (a, b) in yi.iter().zip(&yf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(lin.cache_bytes(), 0, "infer must leave no activation cache");
    }

    #[test]
    fn note_hooks_with_fused_kernels_match_per_step_bitwise() {
        // The batched-training decomposition of this layer: lanes' ys via
        // bias rows + gemv_many, dx via gemm_rowsweep, bookkeeping via
        // note_forward/note_backward. Must carry the serial per-step
        // path's exact bits (here "lanes" play the role of B episodes all
        // doing their step-t forward at once).
        use crate::tensor::matrix::{gemm_rowsweep, gemv_many};
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let mut a = Linear::new("a", 3, 2, &mut r1);
        let mut b = Linear::new("b", 3, 2, &mut r2);
        let xs = vec![
            vec![0.5, -1.0, 2.0],
            vec![1.0, 0.0, 0.0],
            vec![-0.3, 0.7, 0.1],
        ];
        let dys = vec![vec![1.0, -1.0], vec![0.5, 0.5], vec![0.0, 2.0]];

        // Serial per-step path (one lane at a time).
        let mut ys = Vec::new();
        for x in &xs {
            ys.push(a.forward(x));
        }
        let mut dxs = Vec::new();
        for dy in dys.iter().rev() {
            dxs.push(a.backward(dy));
        }
        dxs.reverse();

        // Fused path: all three "lanes" at once.
        let xm = Matrix::from_rows(xs.clone());
        let mut ym = Matrix::zeros(3, 2);
        for l in 0..3 {
            ym.row_mut(l).copy_from_slice(&b.b.w.data);
            b.note_forward(xm.row(l));
        }
        gemv_many(&mut ym, &b.w.w, &xm);
        // LIFO: lanes' note_backwards pop caches newest-first, matching
        // the serial loop's reverse order.
        let dym = Matrix::from_rows(dys.iter().rev().cloned().collect());
        let mut dxm = Matrix::zeros(3, 3);
        gemm_rowsweep(&mut dxm, &dym, &b.w.w);
        for l in 0..3 {
            b.note_backward(dym.row(l));
        }

        for (t, y) in ys.iter().enumerate() {
            for (j, v) in y.iter().enumerate() {
                assert_eq!(v.to_bits(), ym.get(t, j).to_bits(), "y[{t}][{j}]");
            }
            for (j, v) in dxs[t].iter().enumerate() {
                // dxs is in forward order; dxm rows are reversed.
                assert_eq!(v.to_bits(), dxm.get(2 - t, j).to_bits(), "dx[{t}][{j}]");
            }
        }
        for (ga, gb) in a.w.g.data.iter().zip(&b.w.g.data) {
            assert_eq!(ga.to_bits(), gb.to_bits(), "dW mismatch");
        }
        for (ga, gb) in a.b.g.data.iter().zip(&b.b.g.data) {
            assert_eq!(ga.to_bits(), gb.to_bits(), "db mismatch");
        }
    }
}
