//! Fully-connected layer with manual backward and an explicit activation
//! cache stack (supports arbitrarily long BPTT: one push per forward call,
//! one pop per backward call).

use super::param::{HasParams, Param};
use crate::tensor::matrix::{axpy, dot, outer_acc, Matrix};
use crate::util::rng::Rng;

/// y = W x + b.
pub struct Linear {
    pub w: Param, // out × in
    pub b: Param, // 1 × out
    /// Cached inputs, one per un-backpropagated forward call.
    cache_x: Vec<Vec<f32>>,
}

impl Linear {
    pub fn new(name: &str, in_dim: usize, out_dim: usize, rng: &mut Rng) -> Linear {
        Linear {
            w: Param::fan_in(&format!("{name}.w"), out_dim, in_dim, in_dim, rng),
            b: Param::zeros(&format!("{name}.b"), 1, out_dim),
            cache_x: Vec::new(),
        }
    }

    pub fn in_dim(&self) -> usize {
        self.w.w.cols
    }

    pub fn out_dim(&self) -> usize {
        self.w.w.rows
    }

    /// Forward one vector; caches `x` for the matching backward.
    pub fn forward(&mut self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim());
        let mut y = self.b.w.data.clone();
        for (i, yi) in y.iter_mut().enumerate() {
            *yi += dot(self.w.w.row(i), x);
        }
        self.cache_x.push(x.to_vec());
        y
    }

    /// Backward the most recent un-backpropagated forward; accumulates
    /// parameter grads and returns dL/dx.
    pub fn backward(&mut self, dy: &[f32]) -> Vec<f32> {
        assert_eq!(dy.len(), self.out_dim());
        let x = self.cache_x.pop().expect("backward without forward");
        outer_acc(&mut self.w.g, dy, &x);
        axpy(&mut self.b.g.data, 1.0, dy);
        let mut dx = vec![0.0; x.len()];
        for (i, &dyi) in dy.iter().enumerate() {
            if dyi != 0.0 {
                axpy(&mut dx, dyi, self.w.w.row(i));
            }
        }
        dx
    }

    /// Drop any cached activations (episode reset).
    pub fn clear_cache(&mut self) {
        self.cache_x.clear();
    }

    pub fn cache_bytes(&self) -> usize {
        self.cache_x.iter().map(|x| x.capacity() * 4 + 24).sum()
    }
}

impl HasParams for Linear {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.w);
        f(&mut self.b);
    }
}

/// Stateless matrix helper for gradient-check tests: y = Wx+b as pure fn.
pub fn linear_apply(w: &Matrix, b: &[f32], x: &[f32]) -> Vec<f32> {
    let mut y = b.to_vec();
    for (i, yi) in y.iter_mut().enumerate() {
        *yi += dot(w.row(i), x);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_matches_manual() {
        let mut rng = Rng::new(1);
        let mut lin = Linear::new("t", 3, 2, &mut rng);
        lin.w.w.data = vec![1., 2., 3., 4., 5., 6.];
        lin.b.w.data = vec![0.5, -0.5];
        let y = lin.forward(&[1., 1., 1.]);
        assert_eq!(y, vec![6.5, 14.5]);
    }

    #[test]
    fn gradients_match_finite_difference() {
        let mut rng = Rng::new(2);
        let mut lin = Linear::new("t", 4, 3, &mut rng);
        let x: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
        let dy = vec![0.3, -0.7, 0.2];
        // loss = dy . y (linear probe)
        let loss = |lin: &mut Linear, x: &[f32]| -> f32 {
            let y = lin.forward(x);
            lin.cache_x.pop();
            y.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        lin.forward(&x);
        let dx = lin.backward(&dy);
        let eps = 1e-2;
        // check dW
        for k in 0..lin.w.w.data.len() {
            let orig = lin.w.w.data[k];
            lin.w.w.data[k] = orig + eps;
            let lp = loss(&mut lin, &x);
            lin.w.w.data[k] = orig - eps;
            let lm = loss(&mut lin, &x);
            lin.w.w.data[k] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - lin.w.g.data[k]).abs() < 1e-3, "W[{k}]");
        }
        // check dx
        for k in 0..x.len() {
            let mut xp = x.clone();
            xp[k] += eps;
            let lp = loss(&mut lin, &xp);
            xp[k] -= 2.0 * eps;
            let lm = loss(&mut lin, &xp);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - dx[k]).abs() < 1e-3, "x[{k}]");
        }
    }

    #[test]
    fn cache_stack_lifo() {
        let mut rng = Rng::new(3);
        let mut lin = Linear::new("t", 2, 2, &mut rng);
        lin.forward(&[1.0, 0.0]);
        lin.forward(&[0.0, 1.0]);
        // backward for second call first: dW row contributions come from x2.
        lin.backward(&[1.0, 0.0]);
        assert_eq!(lin.w.g.get(0, 1), 1.0); // x2 = e2
        lin.backward(&[1.0, 0.0]);
        assert_eq!(lin.w.g.get(0, 0), 1.0); // x1 = e1
        assert_eq!(lin.cache_bytes(), 0);
    }
}
