//! Pointwise activations and their exact derivatives (in terms of outputs,
//! so the forward caches only the activation values).

/// σ(x), numerically stable on both tails.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// dσ/dx expressed via y = σ(x).
#[inline]
pub fn dsigmoid(y: f32) -> f32 {
    y * (1.0 - y)
}

#[inline]
pub fn tanh(x: f32) -> f32 {
    x.tanh()
}

/// d tanh/dx via y = tanh(x).
#[inline]
pub fn dtanh(y: f32) -> f32 {
    1.0 - y * y
}

/// softplus(x) = log(1 + eˣ), stable.
#[inline]
pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        x.exp().ln_1p()
    }
}

/// d softplus/dx = σ(x).
#[inline]
pub fn dsoftplus(x: f32) -> f32 {
    sigmoid(x)
}

/// "oneplus" = 1 + softplus(x) — the DNC's ≥1 sharpening transform.
#[inline]
pub fn oneplus(x: f32) -> f32 {
    1.0 + softplus(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd(f: impl Fn(f32) -> f32, x: f32) -> f32 {
        let e = 1e-3;
        (f(x + e) - f(x - e)) / (2.0 * e)
    }

    #[test]
    fn sigmoid_stable_tails() {
        assert!((sigmoid(100.0) - 1.0).abs() < 1e-6);
        assert!(sigmoid(-100.0) < 1e-6);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn derivatives_match_fd() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            assert!((dsigmoid(sigmoid(x)) - fd(sigmoid, x)).abs() < 1e-3);
            assert!((dtanh(tanh(x)) - fd(tanh, x)).abs() < 1e-3);
            assert!((dsoftplus(x) - fd(softplus, x)).abs() < 1e-3);
        }
    }

    #[test]
    fn oneplus_at_least_one() {
        for &x in &[-50.0f32, -1.0, 0.0, 5.0] {
            assert!(oneplus(x) >= 1.0);
        }
    }
}
