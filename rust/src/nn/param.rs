//! Trainable parameters: a value matrix, its gradient accumulator, and
//! optimizer slots (RMSProp mean-square / Adam moments live here so the
//! optimizer stays stateless over a `visit_params` walk).

use crate::tensor::matrix::Matrix;
use crate::util::rng::Rng;

/// One trainable tensor (matrices; vectors are 1×n or n×1 matrices).
#[derive(Debug, Clone)]
pub struct Param {
    pub name: String,
    /// Value.
    pub w: Matrix,
    /// Gradient accumulator (zeroed by the optimizer after each update).
    pub g: Matrix,
    /// Optimizer slot 1 (RMSProp mean-square / Adam v).
    pub m1: Matrix,
    /// Optimizer slot 2 (Adam m); lazily sized.
    pub m2: Matrix,
}

impl Param {
    pub fn zeros(name: &str, rows: usize, cols: usize) -> Param {
        Param {
            name: name.to_string(),
            w: Matrix::zeros(rows, cols),
            g: Matrix::zeros(rows, cols),
            m1: Matrix::zeros(rows, cols),
            m2: Matrix::zeros(rows, cols),
        }
    }

    /// Uniform(-bound, bound) init (the classic fan-in scaling).
    pub fn uniform(name: &str, rows: usize, cols: usize, bound: f32, rng: &mut Rng) -> Param {
        let mut p = Param::zeros(name, rows, cols);
        for v in p.w.data.iter_mut() {
            *v = rng.uniform_in(-bound, bound);
        }
        p
    }

    /// Fan-in scaled uniform init: bound = 1/sqrt(fan_in).
    pub fn fan_in(name: &str, rows: usize, cols: usize, fan_in: usize, rng: &mut Rng) -> Param {
        Param::uniform(name, rows, cols, 1.0 / (fan_in as f32).sqrt(), rng)
    }

    pub fn len(&self) -> usize {
        self.w.data.len()
    }

    pub fn zero_grad(&mut self) {
        self.g.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Heap bytes held by this parameter (value, gradient and optimizer
    /// slots). The serving tests use this to assert that shared-weight
    /// sessions hold exactly one copy of the parameters.
    pub fn heap_bytes(&self) -> usize {
        self.w.heap_bytes() + self.g.heap_bytes() + self.m1.heap_bytes() + self.m2.heap_bytes()
    }
}

/// Anything that owns parameters exposes them for the optimizer and for
/// serialization through this visitor.
pub trait HasParams {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param));

    fn param_count(&mut self) -> usize {
        let mut n = 0;
        self.visit_params(&mut |p| n += p.len());
        n
    }

    fn zero_grads(&mut self) {
        self.visit_params(&mut |p| p.zero_grad());
    }

    /// Global L2 norm of all gradients (for clipping diagnostics).
    fn grad_norm(&mut self) -> f32 {
        let mut s = 0.0f32;
        self.visit_params(&mut |p| s += p.g.norm_sq());
        s.sqrt()
    }

    /// Flatten all parameter values (checkpointing).
    fn save_values(&mut self) -> Vec<f32> {
        let mut out = Vec::new();
        self.visit_params(&mut |p| out.extend_from_slice(&p.w.data));
        out
    }

    /// Restore from `save_values` output. Panics on length mismatch.
    fn load_values(&mut self, flat: &[f32]) {
        let mut off = 0;
        self.visit_params(&mut |p| {
            let n = p.w.data.len();
            p.w.data.copy_from_slice(&flat[off..off + n]);
            off += n;
        });
        assert_eq!(off, flat.len(), "checkpoint size mismatch");
    }

    /// Flatten all accumulated gradients (same layout as `save_values`).
    /// The trainers use this to extract per-episode gradients so batch
    /// reduction happens in one fixed order regardless of worker count.
    fn save_grads(&mut self) -> Vec<f32> {
        let mut out = Vec::new();
        self.visit_params(&mut |p| out.extend_from_slice(&p.g.data));
        out
    }

    /// Overwrite all gradient accumulators from `save_grads` output.
    /// Panics on length mismatch.
    fn load_grads(&mut self, flat: &[f32]) {
        let mut off = 0;
        self.visit_params(&mut |p| {
            let n = p.g.data.len();
            p.g.data.copy_from_slice(&flat[off..off + n]);
            off += n;
        });
        assert_eq!(off, flat.len(), "gradient size mismatch");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Two {
        a: Param,
        b: Param,
    }

    impl HasParams for Two {
        fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
            f(&mut self.a);
            f(&mut self.b);
        }
    }

    #[test]
    fn visitor_counts_and_roundtrips() {
        let mut rng = Rng::new(1);
        let mut t = Two {
            a: Param::fan_in("a", 3, 4, 4, &mut rng),
            b: Param::fan_in("b", 2, 2, 2, &mut rng),
        };
        assert_eq!(t.param_count(), 16);
        let saved = t.save_values();
        let orig_a = t.a.w.data.clone();
        t.a.w.data.iter_mut().for_each(|x| *x = 0.0);
        t.load_values(&saved);
        assert_eq!(t.a.w.data, orig_a);
    }

    #[test]
    fn grad_save_load_roundtrip() {
        let mut rng = Rng::new(3);
        let mut t = Two {
            a: Param::fan_in("a", 2, 2, 2, &mut rng),
            b: Param::fan_in("b", 2, 2, 2, &mut rng),
        };
        t.a.g.data = vec![1.0, 2.0, 3.0, 4.0];
        t.b.g.data = vec![5.0, 6.0, 7.0, 8.0];
        let g = t.save_grads();
        assert_eq!(g, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        t.zero_grads();
        t.load_grads(&g);
        assert_eq!(t.save_grads(), g);
    }

    #[test]
    fn grad_norm_and_zero() {
        let mut rng = Rng::new(2);
        let mut t = Two {
            a: Param::fan_in("a", 2, 2, 2, &mut rng),
            b: Param::fan_in("b", 2, 2, 2, &mut rng),
        };
        t.a.g.data = vec![3.0, 0.0, 0.0, 0.0];
        t.b.g.data = vec![4.0, 0.0, 0.0, 0.0];
        assert!((t.grad_norm() - 5.0).abs() < 1e-6);
        t.zero_grads();
        assert_eq!(t.grad_norm(), 0.0);
    }
}
