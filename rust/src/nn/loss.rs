//! Losses: sigmoid cross-entropy over bits (the NTM algorithmic tasks
//! report "bits" error) and softmax cross-entropy over classes (Omniglot /
//! Babi word prediction).

use crate::tensor::matrix::softmax_inplace;

/// Numerically-stable sigmoid cross entropy between logits and {0,1}
/// targets. Returns (loss-sum-in-nats, dL/dlogits).
pub fn sigmoid_xent(logits: &[f32], targets: &[f32]) -> (f32, Vec<f32>) {
    assert_eq!(logits.len(), targets.len());
    let mut loss = 0.0f32;
    let mut grad = vec![0.0f32; logits.len()];
    for i in 0..logits.len() {
        let (l, t) = (logits[i], targets[i]);
        // max(l,0) - l t + log(1 + exp(-|l|))
        loss += l.max(0.0) - l * t + (-l.abs()).exp().ln_1p();
        let s = super::act::sigmoid(l);
        grad[i] = s - t;
    }
    (loss, grad)
}

/// Bits wrong after thresholding logits at 0 (the paper's task metric).
pub fn bit_errors(logits: &[f32], targets: &[f32]) -> usize {
    logits
        .iter()
        .zip(targets)
        .filter(|(&l, &t)| (l > 0.0) != (t > 0.5))
        .count()
}

/// Softmax cross entropy against a 1-hot class index.
/// Returns (loss-nats, dL/dlogits).
pub fn softmax_xent(logits: &[f32], target: usize) -> (f32, Vec<f32>) {
    assert!(target < logits.len());
    let mut p = logits.to_vec();
    softmax_inplace(&mut p);
    let loss = -(p[target].max(1e-12)).ln();
    let mut grad = p;
    grad[target] -= 1.0;
    (loss, grad)
}

/// Argmax helper for classification accuracy.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_xent_matches_fd() {
        let logits = vec![0.5f32, -1.2, 2.0, 0.0];
        let targets = vec![1.0f32, 0.0, 1.0, 0.0];
        let (_, grad) = sigmoid_xent(&logits, &targets);
        let eps = 1e-3;
        for k in 0..logits.len() {
            let mut lp = logits.clone();
            lp[k] += eps;
            let mut lm = logits.clone();
            lm[k] -= eps;
            let fd = (sigmoid_xent(&lp, &targets).0 - sigmoid_xent(&lm, &targets).0) / (2.0 * eps);
            assert!((fd - grad[k]).abs() < 1e-3);
        }
    }

    #[test]
    fn sigmoid_xent_extreme_logits_finite() {
        let (loss, grad) = sigmoid_xent(&[1000.0, -1000.0], &[1.0, 0.0]);
        assert!(loss.is_finite() && loss < 1e-3);
        assert!(grad.iter().all(|g| g.is_finite()));
    }

    #[test]
    fn softmax_xent_matches_fd() {
        let logits = vec![0.1f32, 1.5, -0.7];
        let (_, grad) = softmax_xent(&logits, 2);
        let eps = 1e-3;
        for k in 0..3 {
            let mut lp = logits.clone();
            lp[k] += eps;
            let mut lm = logits.clone();
            lm[k] -= eps;
            let fd = (softmax_xent(&lp, 2).0 - softmax_xent(&lm, 2).0) / (2.0 * eps);
            assert!((fd - grad[k]).abs() < 1e-3);
        }
    }

    #[test]
    fn bit_errors_counts() {
        assert_eq!(bit_errors(&[1.0, -1.0, 1.0], &[1.0, 0.0, 0.0]), 1);
        assert_eq!(bit_errors(&[-1.0, 1.0], &[1.0, 0.0]), 2);
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
    }
}
