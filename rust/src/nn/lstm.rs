//! Single-layer LSTM cell with manual BPTT (the controller of every core,
//! paper §3.3: "We use a one layer LSTM for the controller throughout").
//!
//! Hot-path structure (the controller is the densest compute in every core):
//!
//! * the per-step gate pre-activations are two GEMVs (`Wx·x`, `Wh·h`);
//! * the batched trainer computes both projections lane-fused across B
//!   episodes (`gemv_many`) and enters through [`Lstm::step_with_z`] /
//!   the split [`Lstm::backward_z_into`]+[`Lstm::backward_finish`] pair,
//!   which are bitwise-identical recompositions of the serial hot path
//!   (see DESIGN.md "Batched training");
//! * the backward pass defers both weight gradients: instead of two rank-1
//!   `outer_acc` updates per step it queues (dz, x, h_prev) rows and folds
//!   the episode in as `dWx += dZᵀ X`, `dWh += dZᵀ H` — two GEMMs — when
//!   the tape empties (or on [`Lstm::reset`]). Same flops, cache-friendly,
//!   and one deterministic summation order shared by the serial and
//!   data-parallel trainers.
//! * every tape/scratch buffer is drawn from a layer-private [`Workspace`]
//!   and recycled when its step is backpropagated, so steady-state steps
//!   allocate nothing: [`Lstm::step_hot`] leaves h_t in `self.h`, and
//!   [`Lstm::backward_into`] writes dx into a caller-reused buffer. The
//!   allocating [`Lstm::step`]/[`Lstm::backward`] wrappers remain for cold
//!   callers and tests.

use super::act::{dsigmoid, dtanh, sigmoid, tanh};
use super::param::{HasParams, Param};
use crate::tensor::matrix::{axpy, col_sum_acc, gemm_tn, gemv};
use crate::tensor::workspace::Workspace;
use crate::util::rng::Rng;

/// Detached recurrent state for forward-only inference: everything an
/// [`Lstm::infer_step`] mutates. The `Lstm` itself is only read, so one
/// set of trained weights (behind an `Arc`) can drive any number of
/// concurrent `LstmState`s — the parameters/state split the serving
/// runtime is built on.
pub struct LstmState {
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    /// Gate pre-activation scratch (fixed shape, reused every step).
    z: Vec<f32>,
}

impl LstmState {
    /// Zero the recurrent state (episode boundary).
    pub fn reset(&mut self) {
        self.h.iter_mut().for_each(|x| *x = 0.0);
        self.c.iter_mut().for_each(|x| *x = 0.0);
    }

    pub fn heap_bytes(&self) -> usize {
        (self.h.capacity() + self.c.capacity() + self.z.capacity()) * 4
    }
}

/// Per-step cache for the backward pass (all buffers workspace-pooled).
struct StepCache {
    x: Vec<f32>,
    h_prev: Vec<f32>,
    c_prev: Vec<f32>,
    /// Gate activations [i, f, g, o], each of length H.
    gates: Vec<f32>,
    c: Vec<f32>,
}

/// LSTM cell. Gate order in the packed weight matrices: i, f, g, o.
pub struct Lstm {
    pub hidden: usize,
    pub input: usize,
    pub wx: Param, // 4H × I
    pub wh: Param, // 4H × H
    pub b: Param,  // 1 × 4H
    /// Current recurrent state.
    pub h: Vec<f32>,
    pub c: Vec<f32>,
    /// Carried gradient state during the backward sweep.
    dh_next: Vec<f32>,
    dc_next: Vec<f32>,
    tape: Vec<StepCache>,
    /// (dz, x, h_prev) rows awaiting the episode-level GEMM gradient flush.
    pending: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
    /// (x, h_prev) of the step between [`Lstm::backward_z_into`] and
    /// [`Lstm::backward_finish`] on the split (batched) backward path.
    staged: Option<(Vec<f32>, Vec<f32>)>,
    /// Layer-private buffer pool; tape buffers never escape the layer, so
    /// the take/recycle cycle closes here.
    ws: Workspace,
    forget_bias: f32,
}

impl Lstm {
    pub fn new(name: &str, input: usize, hidden: usize, rng: &mut Rng) -> Lstm {
        Lstm {
            hidden,
            input,
            wx: Param::fan_in(&format!("{name}.wx"), 4 * hidden, input, input, rng),
            wh: Param::fan_in(&format!("{name}.wh"), 4 * hidden, hidden, hidden, rng),
            b: Param::zeros(&format!("{name}.b"), 1, 4 * hidden),
            h: vec![0.0; hidden],
            c: vec![0.0; hidden],
            dh_next: vec![0.0; hidden],
            dc_next: vec![0.0; hidden],
            tape: Vec::new(),
            pending: Vec::new(),
            staged: None,
            ws: Workspace::new(),
            forget_bias: 1.0,
        }
    }

    /// Reset recurrent state and drop the tape (episode boundary). A
    /// partially backpropagated episode's queued weight gradients are
    /// flushed first so truncated BPTT keeps its gradients.
    pub fn reset(&mut self) {
        self.flush_grads();
        self.h.iter_mut().for_each(|x| *x = 0.0);
        self.c.iter_mut().for_each(|x| *x = 0.0);
        self.dh_next.iter_mut().for_each(|x| *x = 0.0);
        self.dc_next.iter_mut().for_each(|x| *x = 0.0);
        while let Some(cache) = self.tape.pop() {
            self.recycle_cache(cache);
        }
        if let Some((x, h_prev)) = self.staged.take() {
            self.ws.recycle_f32(x);
            self.ws.recycle_f32(h_prev);
        }
    }

    fn recycle_cache(&mut self, cache: StepCache) {
        self.ws.recycle_f32(cache.x);
        self.ws.recycle_f32(cache.h_prev);
        self.ws.recycle_f32(cache.c_prev);
        self.ws.recycle_f32(cache.gates);
        self.ws.recycle_f32(cache.c);
    }

    /// One forward step; h_t is left in `self.h` (no allocation).
    pub fn step_hot(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.input);
        let mut zx = self.ws.take_f32(4 * self.hidden);
        gemv(&mut zx, &self.wx.w, x);
        let xb = self.ws.take_f32_copy(x);
        self.step_with_zx(xb, zx);
    }

    /// One forward step; returns h_t (also kept in `self.h`). Allocating
    /// wrapper over [`Lstm::step_hot`].
    pub fn step(&mut self, x: &[f32]) -> Vec<f32> {
        self.step_hot(x);
        self.h.clone()
    }

    /// Fresh zeroed inference state sized for this cell.
    pub fn new_state(&self) -> LstmState {
        LstmState {
            h: vec![0.0; self.hidden],
            c: vec![0.0; self.hidden],
            z: vec![0.0; 4 * self.hidden],
        }
    }

    /// Forward-only step against shared read-only weights: no tape, no
    /// cache, no gradient state — h_t lands in `st.h`. The float-op order
    /// matches [`Lstm::step_hot`] exactly (same `gemv`/`axpy` calls, same
    /// gate expressions), so infer-mode outputs are bit-identical to
    /// train-mode forwards.
    pub fn infer_step(&self, st: &mut LstmState, x: &[f32]) {
        assert_eq!(x.len(), self.input);
        st.z.clear();
        st.z.resize(4 * self.hidden, 0.0);
        gemv(&mut st.z, &self.wx.w, x);
        self.infer_apply_gates(st);
    }

    /// Second half of an infer step: `st.z` holds Wx·x; adds b + Wh·h and
    /// applies the gate nonlinearity, updating `st.h`/`st.c` in place.
    fn infer_apply_gates(&self, st: &mut LstmState) {
        axpy(&mut st.z, 1.0, &self.b.w.data);
        gemv(&mut st.z, &self.wh.w, &st.h);
        self.infer_nonlin(st);
    }

    /// Batched-tick entry: consume externally computed gate pre-activations
    /// z = Wx·x + b + Wh·h (one session's rows of the tick's coalesced
    /// GEMMs) and apply the gate nonlinearity.
    pub fn infer_step_with_z(&self, st: &mut LstmState, z: &[f32]) {
        assert_eq!(z.len(), 4 * self.hidden);
        st.z.clear();
        st.z.extend_from_slice(z);
        self.infer_nonlin(st);
    }

    /// The gate nonlinearity over `st.z`, updating `st.h`/`st.c`.
    fn infer_nonlin(&self, st: &mut LstmState) {
        let hs = self.hidden;
        for j in 0..hs {
            let i = sigmoid(st.z[j]);
            let f = sigmoid(st.z[hs + j] + self.forget_bias);
            let g = tanh(st.z[2 * hs + j]);
            let o = sigmoid(st.z[3 * hs + j]);
            let c_new = f * st.c[j] + i * g;
            st.c[j] = c_new;
            st.h[j] = o * tanh(c_new);
        }
    }

    /// Heap bytes of the weight matrices (value + optimizer slots) — the
    /// "one copy regardless of session count" quantity the serving tests
    /// assert on.
    pub fn params_heap_bytes(&self) -> usize {
        self.wx.heap_bytes() + self.wh.heap_bytes() + self.b.heap_bytes()
    }

    /// Shared step body: `z` arrives holding Wx·x and picks up b + Wh·h.
    /// Takes ownership of (pooled or fresh) `x`/`z` buffers; `x` goes to
    /// the tape, `z` is recycled.
    fn step_with_zx(&mut self, x: Vec<f32>, mut z: Vec<f32>) {
        axpy(&mut z, 1.0, &self.b.w.data);
        gemv(&mut z, &self.wh.w, &self.h);
        self.step_tail(x, z);
    }

    /// Batched-training forward entry: consume fully assembled gate
    /// pre-activations z = (Wx·x + b) + Wh·h_prev, tape the step and update
    /// h/c — [`Lstm::step_hot`] minus the two projections, which the
    /// batched trainer runs lane-fused (`gemv_many`) across B episodes.
    /// Bitwise contract: `gemv` adds each complete dot onto the running z
    /// exactly once, so a caller that assembles `(zx[i] + b[i]) + zh[i]`
    /// per element (zx/zh each a plain dot into a zeroed row) reproduces
    /// [`Lstm::step_with_zx`]'s z bits, and everything downstream of z is
    /// shared code.
    pub fn step_with_z(&mut self, x: &[f32], z: &[f32]) {
        assert_eq!(x.len(), self.input);
        assert_eq!(z.len(), 4 * self.hidden);
        let xb = self.ws.take_f32_copy(x);
        let zb = self.ws.take_f32_copy(z);
        self.step_tail(xb, zb);
    }

    /// Gate nonlinearity + state update + tape push over an assembled z
    /// (the common tail of [`Lstm::step_with_zx`] / [`Lstm::step_with_z`]).
    fn step_tail(&mut self, x: Vec<f32>, z: Vec<f32>) {
        let hs = self.hidden;
        let mut gates = self.ws.take_f32(4 * hs);
        for j in 0..hs {
            gates[j] = sigmoid(z[j]); // i
            gates[hs + j] = sigmoid(z[hs + j] + self.forget_bias); // f
            gates[2 * hs + j] = tanh(z[2 * hs + j]); // g
            gates[3 * hs + j] = sigmoid(z[3 * hs + j]); // o
        }
        let mut c_new = self.ws.take_f32(hs);
        let mut h_new = self.ws.take_f32(hs);
        for j in 0..hs {
            // self.c/self.h still hold c_{t-1}/h_{t-1} here.
            c_new[j] = gates[hs + j] * self.c[j] + gates[j] * gates[2 * hs + j];
            h_new[j] = gates[3 * hs + j] * tanh(c_new[j]);
        }
        let c_prev = std::mem::replace(&mut self.c, c_new);
        let h_prev = std::mem::replace(&mut self.h, h_new);
        let c_copy = self.ws.take_f32_copy(&self.c);
        self.ws.recycle_f32(z);
        self.tape.push(StepCache { x, h_prev, c_prev, gates, c: c_copy });
    }

    /// Backward the most recent un-backpropagated step, writing dL/dx_t
    /// into the caller-reused `dx` buffer (cleared and resized here). `dh`
    /// is dL/dh_t from this step's consumers; the recurrent grads (from
    /// t+1) are carried internally. Weight gradients are queued and folded
    /// in as two GEMMs when the last taped step has been backpropagated.
    pub fn backward_into(&mut self, dh_ext: &[f32], dx: &mut Vec<f32>) {
        let cache = self.tape.pop().expect("lstm backward without forward");
        let hs = self.hidden;
        let mut dh = self.ws.take_f32_copy(dh_ext);
        axpy(&mut dh, 1.0, &self.dh_next);
        let mut dz = self.ws.take_f32(4 * hs);
        let mut dc_prev = self.ws.take_f32(hs);
        for j in 0..hs {
            let (i, f, g, o) = (
                cache.gates[j],
                cache.gates[hs + j],
                cache.gates[2 * hs + j],
                cache.gates[3 * hs + j],
            );
            let tc = tanh(cache.c[j]);
            let d_o = dh[j] * tc;
            let dc = self.dc_next[j] + dh[j] * o * dtanh(tc);
            let d_i = dc * g;
            let d_f = dc * cache.c_prev[j];
            let d_g = dc * i;
            dc_prev[j] = dc * f;
            dz[j] = d_i * dsigmoid(i);
            dz[hs + j] = d_f * dsigmoid(f);
            dz[2 * hs + j] = d_g * dtanh(g);
            dz[3 * hs + j] = d_o * dsigmoid(o);
        }
        // Input grad and carried recurrent grads (need W, not the caches).
        dx.clear();
        dx.resize(self.input, 0.0);
        let mut dh_prev = self.ws.take_f32(hs);
        for (r, &dzr) in dz.iter().enumerate() {
            if dzr != 0.0 {
                axpy(dx, dzr, self.wx.w.row(r));
                axpy(&mut dh_prev, dzr, self.wh.w.row(r));
            }
        }
        let old = std::mem::replace(&mut self.dh_next, dh_prev);
        self.ws.recycle_f32(old);
        let old = std::mem::replace(&mut self.dc_next, dc_prev);
        self.ws.recycle_f32(old);
        self.ws.recycle_f32(dh);
        self.ws.recycle_f32(cache.gates);
        self.ws.recycle_f32(cache.c);
        // Defer the weight gradients to the episode-level GEMM flush.
        self.pending.push((dz, cache.x, cache.h_prev));
        self.ws.recycle_f32(cache.c_prev);
        if self.tape.is_empty() {
            self.flush_grads();
        }
    }

    /// Allocating wrapper over [`Lstm::backward_into`].
    pub fn backward(&mut self, dh_ext: &[f32]) -> Vec<f32> {
        let mut dx = Vec::new();
        self.backward_into(dh_ext, &mut dx);
        dx
    }

    /// First half of the split (batched) backward step: pop the newest
    /// taped step, run the elementwise gate backward — consuming the
    /// carried dh_next/dc_next and updating dc_next — and write dL/dz into
    /// `dz_out` (length 4H, typically a lane's row of the batched dZ
    /// matrix). The step's (x, h_prev) are staged for
    /// [`Lstm::backward_finish`]; the caller turns the lanes' dZ rows into
    /// dX / dH_prev with lane-fused `gemm_rowsweep`s against Wx / Wh.
    /// This is exactly [`Lstm::backward_into`]'s per-j loop, so dz bits
    /// match the serial path.
    pub fn backward_z_into(&mut self, dh_ext: &[f32], dz_out: &mut [f32]) {
        let cache = self.tape.pop().expect("lstm backward without forward");
        let hs = self.hidden;
        assert_eq!(dz_out.len(), 4 * hs);
        let mut dh = self.ws.take_f32_copy(dh_ext);
        axpy(&mut dh, 1.0, &self.dh_next);
        let mut dc_prev = self.ws.take_f32(hs);
        for j in 0..hs {
            let (i, f, g, o) = (
                cache.gates[j],
                cache.gates[hs + j],
                cache.gates[2 * hs + j],
                cache.gates[3 * hs + j],
            );
            let tc = tanh(cache.c[j]);
            let d_o = dh[j] * tc;
            let dc = self.dc_next[j] + dh[j] * o * dtanh(tc);
            let d_i = dc * g;
            let d_f = dc * cache.c_prev[j];
            let d_g = dc * i;
            dc_prev[j] = dc * f;
            dz_out[j] = d_i * dsigmoid(i);
            dz_out[hs + j] = d_f * dsigmoid(f);
            dz_out[2 * hs + j] = d_g * dtanh(g);
            dz_out[3 * hs + j] = d_o * dsigmoid(o);
        }
        let old = std::mem::replace(&mut self.dc_next, dc_prev);
        self.ws.recycle_f32(old);
        self.ws.recycle_f32(dh);
        self.ws.recycle_f32(cache.gates);
        self.ws.recycle_f32(cache.c);
        self.ws.recycle_f32(cache.c_prev);
        self.staged = Some((cache.x, cache.h_prev));
    }

    /// Second half of the split backward step: consume this lane's dZ row
    /// (queued with the staged x/h_prev for the episode-level GEMM flush)
    /// and its dH_prev row (→ the carried dh_next), flushing when the tape
    /// empties. `dh_prev` must be the lane's row of a zero-initialized
    /// dH_prev accumulator swept with dZ·Wh — which is bit-for-bit the
    /// serial backward's own dh_prev (zeroed pooled buffer + the same axpy
    /// sequence).
    pub fn backward_finish(&mut self, dz: &[f32], dh_prev: &[f32]) {
        let (x, h_prev) =
            self.staged.take().expect("backward_finish without backward_z_into");
        assert_eq!(dh_prev.len(), self.hidden);
        self.dh_next.copy_from_slice(dh_prev);
        let dzb = self.ws.take_f32_copy(dz);
        self.pending.push((dzb, x, h_prev));
        if self.tape.is_empty() {
            self.flush_grads();
        }
    }

    /// Fold all queued per-step weight gradients in as two GEMMs:
    /// dWx += dZᵀ X, dWh += dZᵀ H_prev, db += colsum(dZ).
    fn flush_grads(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let t = self.pending.len();
        let mut dz = self.ws.take_matrix(t, 4 * self.hidden);
        let mut x = self.ws.take_matrix(t, self.input);
        let mut hp = self.ws.take_matrix(t, self.hidden);
        let mut pending = std::mem::take(&mut self.pending);
        for (r, (dzr, xr, hr)) in pending.drain(..).enumerate() {
            dz.row_mut(r).copy_from_slice(&dzr);
            x.row_mut(r).copy_from_slice(&xr);
            hp.row_mut(r).copy_from_slice(&hr);
            self.ws.recycle_f32(dzr);
            self.ws.recycle_f32(xr);
            self.ws.recycle_f32(hr);
        }
        self.pending = pending;
        gemm_tn(&mut self.wx.g, &dz, &x);
        gemm_tn(&mut self.wh.g, &dz, &hp);
        col_sum_acc(&mut self.b.g.data, &dz);
        self.ws.recycle_matrix(dz);
        self.ws.recycle_matrix(x);
        self.ws.recycle_matrix(hp);
    }

    pub fn tape_len(&self) -> usize {
        self.tape.len()
    }

    pub fn cache_bytes(&self) -> usize {
        self.tape
            .iter()
            .map(|s| {
                (s.x.capacity()
                    + s.h_prev.capacity()
                    + s.c_prev.capacity()
                    + s.gates.capacity()
                    + s.c.capacity())
                    * 4
                    + 5 * 24
            })
            .sum::<usize>()
            + self
                .pending
                .iter()
                .map(|(a, b, c)| (a.capacity() + b.capacity() + c.capacity()) * 4 + 72)
                .sum::<usize>()
    }
}

impl HasParams for Lstm {
    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.wx);
        f(&mut self.wh);
        f(&mut self.b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matrix::dot;

    /// Run T steps, probe-loss = Σ_t probe_t · h_t. Used for FD checks.
    fn run_loss(lstm: &mut Lstm, xs: &[Vec<f32>], probes: &[Vec<f32>]) -> f32 {
        lstm.reset();
        let mut loss = 0.0;
        for (x, p) in xs.iter().zip(probes) {
            let h = lstm.step(x);
            loss += dot(&h, p);
        }
        loss
    }

    #[test]
    fn bptt_gradients_match_fd() {
        let (input, hidden, t_len) = (3, 4, 5);
        let mut rng = Rng::new(10);
        let mut lstm = Lstm::new("t", input, hidden, &mut rng);
        let xs: Vec<Vec<f32>> = (0..t_len)
            .map(|_| (0..input).map(|_| rng.normal()).collect())
            .collect();
        let probes: Vec<Vec<f32>> = (0..t_len)
            .map(|_| (0..hidden).map(|_| rng.normal()).collect())
            .collect();

        // Analytic grads.
        run_loss(&mut lstm, &xs, &probes);
        let mut dxs = Vec::new();
        for t in (0..t_len).rev() {
            dxs.push(lstm.backward(&probes[t]));
        }
        dxs.reverse();

        let eps = 1e-2f32;
        // Check all wx entries and a few wh/b entries.
        let mut checked = 0;
        for (pi, idxs) in [(0usize, 0..12usize), (1, 0..8), (2, 0..8)] {
            for k in idxs {
                let (orig, an) = {
                    let p = match pi {
                        0 => &mut lstm.wx,
                        1 => &mut lstm.wh,
                        _ => &mut lstm.b,
                    };
                    if k >= p.w.data.len() {
                        continue;
                    }
                    (p.w.data[k], p.g.data[k])
                };
                let set = |l: &mut Lstm, v: f32| match pi {
                    0 => l.wx.w.data[k] = v,
                    1 => l.wh.w.data[k] = v,
                    _ => l.b.w.data[k] = v,
                };
                set(&mut lstm, orig + eps);
                let lp = run_loss(&mut lstm, &xs, &probes);
                set(&mut lstm, orig - eps);
                let lm = run_loss(&mut lstm, &xs, &probes);
                set(&mut lstm, orig);
                let fd = (lp - lm) / (2.0 * eps);
                let err = (fd - an).abs() / (1.0f32).max(fd.abs());
                assert!(err < 2e-2, "param {pi} [{k}]: fd={fd} an={an}");
                checked += 1;
            }
        }
        assert!(checked > 20);

        // Check dx at t=0 (full recurrent path).
        lstm.reset();
        for k in 0..input {
            let mut xp = xs.clone();
            xp[0][k] += eps;
            let lp = run_loss(&mut lstm, &xp, &probes);
            xp[0][k] -= 2.0 * eps;
            let lm = run_loss(&mut lstm, &xp, &probes);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - dxs[0][k]).abs() < 2e-2, "dx[{k}]: fd={fd} an={}", dxs[0][k]);
        }
    }

    #[test]
    fn reset_clears_state() {
        let mut rng = Rng::new(11);
        let mut lstm = Lstm::new("t", 2, 3, &mut rng);
        lstm.step(&[1.0, -1.0]);
        assert!(lstm.h.iter().any(|&x| x != 0.0));
        lstm.reset();
        assert!(lstm.h.iter().all(|&x| x == 0.0));
        assert_eq!(lstm.tape_len(), 0);
        assert_eq!(lstm.cache_bytes(), 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let mut a = Lstm::new("a", 2, 2, &mut r1);
        let mut b = Lstm::new("b", 2, 2, &mut r2);
        assert_eq!(a.step(&[0.5, 0.5]), b.step(&[0.5, 0.5]));
    }

    #[test]
    fn split_step_and_backward_match_hot_path_bitwise() {
        // The batched entry points (externally assembled z, split
        // backward with lane-fused dZ sweeps) must carry exactly the
        // serial hot path's bits — the cell-level leg of the batched-vs-
        // serial training contract.
        use crate::tensor::matrix::{gemm_rowsweep, Matrix};
        let (input, hidden, t_len) = (3, 5, 7);
        let mut r1 = Rng::new(12);
        let mut r2 = Rng::new(12);
        let mut a = Lstm::new("a", input, hidden, &mut r1);
        let mut b = Lstm::new("b", input, hidden, &mut r2);
        let mut xr = Rng::new(13);
        let xs: Vec<Vec<f32>> = (0..t_len)
            .map(|_| (0..input).map(|_| xr.normal()).collect())
            .collect();
        for ep in 0..2 {
            for (t, x) in xs.iter().enumerate() {
                a.step_hot(x);
                // The batched trainer's assembly: both projections as
                // plain dots into zeroed rows, then (zx + b) + zh.
                let mut zx = vec![0.0f32; 4 * hidden];
                gemv(&mut zx, &b.wx.w, x);
                let mut zh = vec![0.0f32; 4 * hidden];
                gemv(&mut zh, &b.wh.w, &b.h);
                let z: Vec<f32> = (0..4 * hidden)
                    .map(|i| (zx[i] + b.b.w.data[i]) + zh[i])
                    .collect();
                b.step_with_z(x, &z);
                for (ha, hb) in a.h.iter().zip(&b.h) {
                    assert_eq!(ha.to_bits(), hb.to_bits(), "h ep {ep} t {t}");
                }
                for (ca, cb) in a.c.iter().zip(&b.c) {
                    assert_eq!(ca.to_bits(), cb.to_bits(), "c ep {ep} t {t}");
                }
            }
            let probe = vec![0.3f32, -0.2, 0.5, 0.1, -0.4];
            let mut dx_a = Vec::new();
            for t in 0..t_len {
                a.backward_into(&probe, &mut dx_a);
                let mut dz = Matrix::zeros(1, 4 * hidden);
                b.backward_z_into(&probe, dz.row_mut(0));
                let mut dx_b = Matrix::zeros(1, input);
                let mut dh_prev = Matrix::zeros(1, hidden);
                gemm_rowsweep(&mut dx_b, &dz, &b.wx.w);
                gemm_rowsweep(&mut dh_prev, &dz, &b.wh.w);
                b.backward_finish(dz.row(0), dh_prev.row(0));
                for (da, db) in dx_a.iter().zip(dx_b.row(0)) {
                    assert_eq!(da.to_bits(), db.to_bits(), "dx ep {ep} t {t}");
                }
            }
            for (p, q) in [(&a.wx, &b.wx), (&a.wh, &b.wh), (&a.b, &b.b)] {
                for (ga, gb) in p.g.data.iter().zip(&q.g.data) {
                    assert_eq!(ga.to_bits(), gb.to_bits(), "grads ep {ep}");
                }
            }
            a.reset();
            b.reset();
        }
    }

    #[test]
    fn truncated_backward_keeps_grads_on_reset() {
        let mut rng = Rng::new(14);
        let mut lstm = Lstm::new("t", 2, 3, &mut rng);
        lstm.step(&[1.0, 0.0]);
        lstm.step(&[0.0, 1.0]);
        lstm.backward(&[1.0, 1.0, 1.0]); // only the last step
        assert_eq!(lstm.wx.g.norm_sq(), 0.0, "grads deferred while tape live");
        lstm.reset();
        assert!(lstm.wx.g.norm_sq() > 0.0, "reset must flush queued grads");
    }

    #[test]
    fn infer_step_matches_train_step_bitwise() {
        // The params/state split must not move a single bit: a detached
        // LstmState driven by &self must track step_hot exactly.
        let mut rng = Rng::new(21);
        let mut lstm = Lstm::new("t", 3, 5, &mut rng);
        let mut st = lstm.new_state();
        let xs = [[0.4f32, -0.9, 0.1], [1.2, 0.0, -0.3], [0.0, 0.7, 0.7]];
        for ep in 0..2 {
            for x in &xs {
                lstm.step_hot(x);
                lstm.infer_step(&mut st, x);
                for (a, b) in lstm.h.iter().zip(&st.h) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ep {ep}");
                }
                for (a, b) in lstm.c.iter().zip(&st.c) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ep {ep}");
                }
            }
            lstm.reset();
            st.reset();
            assert!(st.h.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn hot_path_reuses_buffers_without_changing_values() {
        // Same seed, hot vs wrapper API: identical h and gradients.
        let mut r1 = Rng::new(15);
        let mut r2 = Rng::new(15);
        let mut a = Lstm::new("a", 3, 4, &mut r1);
        let mut b = Lstm::new("b", 3, 4, &mut r2);
        let xs = [[0.3f32, -1.0, 0.5], [1.0, 0.2, -0.7]];
        let mut dx = Vec::new();
        for ep in 0..3 {
            for x in &xs {
                a.step_hot(x);
                let hb = b.step(x);
                assert_eq!(a.h, hb, "ep {ep}");
            }
            for _ in 0..xs.len() {
                a.backward_into(&[1.0, 0.5, -0.5, 0.25], &mut dx);
                let dxb = b.backward(&[1.0, 0.5, -0.5, 0.25]);
                assert_eq!(dx, dxb, "ep {ep}");
            }
            for (ga, gb) in a.wx.g.data.iter().zip(&b.wx.g.data) {
                assert_eq!(ga.to_bits(), gb.to_bits(), "ep {ep}");
            }
            a.reset();
            b.reset();
        }
    }
}
