//! Synthetic Babi-style question answering (paper §4.4, Tables 1-2).
//!
//! **Substitution** (documented in DESIGN.md): the licensed bAbI download
//! is unavailable offline, so we generate stories from the same recipe
//! Weston et al. used — a simulated world of actors, objects and locations
//! with template sentences over a ~150-word vocabulary — covering eight of
//! the twenty task families. Stories stream one 1-hot word per step; the
//! model must emit the answer word at the step after the question mark.
//! This exercises the identical model path (long-context fact retrieval
//! from memory) and yields the same-shaped per-family error table.

use super::{Episode, LossKind, Task};
use crate::util::rng::Rng;
use std::collections::HashMap;

pub const FAMILIES: [&str; 8] = [
    "1:one-supporting-fact",
    "2:two-supporting-facts",
    "5:three-arg-relations",
    "6:yes-no",
    "7:counting",
    "8:lists-sets",
    "11:coreference",
    "16:induction",
];

const ACTORS: [&str; 6] = ["john", "mary", "sandra", "daniel", "bill", "julie"];
const LOCATIONS: [&str; 8] = [
    "kitchen", "garden", "office", "bathroom", "bedroom", "hallway", "park", "school",
];
const OBJECTS: [&str; 6] = ["apple", "football", "milk", "book", "key", "hammer"];
const ANIMALS: [&str; 4] = ["frog", "swan", "lion", "rhino"];
const COLORS: [&str; 4] = ["green", "white", "yellow", "gray"];
const NUMBERS: [&str; 5] = ["zero", "one", "two", "three", "four"];
const MISC: [&str; 18] = [
    "went", "to", "picked", "up", "dropped", "gave", "where", "is", "what", "how", "many",
    "carrying", "objects", "yes", "no", "none", "he", "she",
];
const PUNCT: [&str; 2] = [".", "?"];

/// Word-level 1-hot vocabulary shared by all families.
pub struct Vocab {
    words: Vec<String>,
    index: HashMap<String, usize>,
}

impl Vocab {
    pub fn build() -> Vocab {
        let mut words: Vec<String> = Vec::new();
        for list in [
            &ACTORS[..],
            &LOCATIONS[..],
            &OBJECTS[..],
            &ANIMALS[..],
            &COLORS[..],
            &NUMBERS[..],
            &MISC[..],
            &PUNCT[..],
        ] {
            for w in list {
                words.push((*w).to_string());
            }
        }
        let index = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i))
            .collect();
        Vocab { words, index }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn id(&self, w: &str) -> usize {
        *self
            .index
            .get(w)
            .unwrap_or_else(|| panic!("word {w:?} not in vocab"))
    }

    pub fn word(&self, id: usize) -> &str {
        &self.words[id]
    }
}

/// A story being generated: sentences (word lists) plus the final question
/// and its one-word answer.
struct Qa {
    sentences: Vec<Vec<String>>,
    question: Vec<String>,
    answer: String,
}

pub struct BabiTask {
    pub vocab: Vocab,
    /// Restrict generation to one family (None = sample uniformly — the
    /// paper's joint training).
    pub only_family: Option<usize>,
}

impl BabiTask {
    pub fn new() -> BabiTask {
        BabiTask { vocab: Vocab::build(), only_family: None }
    }

    pub fn family(fam: usize) -> BabiTask {
        BabiTask { vocab: Vocab::build(), only_family: Some(fam) }
    }

    fn s(words: &[&str]) -> Vec<String> {
        words.iter().map(|w| w.to_string()).collect()
    }

    /// family 0 — one supporting fact: track an actor's latest location.
    fn gen_one_fact(&self, n_facts: usize, rng: &mut Rng) -> Qa {
        let mut locs: HashMap<&str, &str> = HashMap::new();
        let mut sentences = Vec::new();
        for _ in 0..n_facts {
            let a = ACTORS[rng.below(ACTORS.len())];
            let l = LOCATIONS[rng.below(LOCATIONS.len())];
            locs.insert(a, l);
            sentences.push(Self::s(&[a, "went", "to", l, "."]));
        }
        let known: Vec<&&str> = locs.keys().collect();
        let a = *known[rng.below(known.len())];
        Qa {
            sentences,
            question: Self::s(&["where", "is", a, "?"]),
            answer: locs[&a[..]].to_string(),
        }
    }

    /// family 1 — two supporting facts: where is the object? (actor carried
    /// it somewhere).
    fn gen_two_facts(&self, n_facts: usize, rng: &mut Rng) -> Qa {
        let mut locs: HashMap<&str, &str> = HashMap::new();
        let mut holding: HashMap<&str, &str> = HashMap::new(); // object -> actor
        let mut sentences = Vec::new();
        // Seed: someone picks up the queried object.
        let obj = OBJECTS[rng.below(OBJECTS.len())];
        let holder = ACTORS[rng.below(ACTORS.len())];
        holding.insert(obj, holder);
        sentences.push(Self::s(&[holder, "picked", "up", obj, "."]));
        let l0 = LOCATIONS[rng.below(LOCATIONS.len())];
        locs.insert(holder, l0);
        sentences.push(Self::s(&[holder, "went", "to", l0, "."]));
        for _ in 0..n_facts {
            let a = ACTORS[rng.below(ACTORS.len())];
            let l = LOCATIONS[rng.below(LOCATIONS.len())];
            locs.insert(a, l);
            sentences.push(Self::s(&[a, "went", "to", l, "."]));
        }
        let answer = locs[holding[obj]].to_string();
        Qa { sentences, question: Self::s(&["where", "is", obj, "?"]), answer }
    }

    /// family 2 — three-argument relations: "gave" transfers possession.
    fn gen_three_arg(&self, n_facts: usize, rng: &mut Rng) -> Qa {
        let obj = OBJECTS[rng.below(OBJECTS.len())];
        let mut owner = ACTORS[rng.below(ACTORS.len())];
        let mut sentences = vec![Self::s(&[owner, "picked", "up", obj, "."])];
        for _ in 0..n_facts.max(1) {
            let next = ACTORS[rng.below(ACTORS.len())];
            if next == owner {
                continue;
            }
            sentences.push(Self::s(&[owner, "gave", obj, "to", next, "."]));
            owner = next;
        }
        Qa {
            sentences,
            question: Self::s(&["where", "is", obj, "carrying", "?"]), // "who is carrying obj"
            answer: owner.to_string(),
        }
    }

    /// family 3 — yes/no questions: is actor in location?
    fn gen_yes_no(&self, n_facts: usize, rng: &mut Rng) -> Qa {
        let mut locs: HashMap<&str, &str> = HashMap::new();
        let mut sentences = Vec::new();
        for _ in 0..n_facts.max(1) {
            let a = ACTORS[rng.below(ACTORS.len())];
            let l = LOCATIONS[rng.below(LOCATIONS.len())];
            locs.insert(a, l);
            sentences.push(Self::s(&[a, "went", "to", l, "."]));
        }
        let known: Vec<&&str> = locs.keys().collect();
        let a = *known[rng.below(known.len())];
        let actual = locs[&a[..]];
        let asked = if rng.bernoulli(0.5) {
            actual
        } else {
            LOCATIONS[rng.below(LOCATIONS.len())]
        };
        let answer = if asked == actual { "yes" } else { "no" };
        Qa {
            sentences,
            question: Self::s(&["is", &a, "to", asked, "?"]),
            answer: answer.to_string(),
        }
    }

    /// family 4 — counting: how many objects is the actor carrying?
    fn gen_counting(&self, n_facts: usize, rng: &mut Rng) -> Qa {
        let a = ACTORS[rng.below(ACTORS.len())];
        let mut count: usize = 0;
        let mut held: Vec<&str> = Vec::new();
        let mut sentences = Vec::new();
        for _ in 0..n_facts.max(2) {
            if !held.is_empty() && rng.bernoulli(0.35) {
                let i = rng.below(held.len());
                let o = held.remove(i);
                count -= 1;
                sentences.push(Self::s(&[a, "dropped", o, "."]));
            } else if count < NUMBERS.len() - 1 {
                let o = OBJECTS[rng.below(OBJECTS.len())];
                if held.contains(&o) {
                    continue;
                }
                held.push(o);
                count += 1;
                sentences.push(Self::s(&[a, "picked", "up", o, "."]));
            }
        }
        Qa {
            sentences,
            question: Self::s(&["how", "many", "objects", &a, "?"]),
            answer: NUMBERS[count].to_string(),
        }
    }

    /// family 5 — lists/sets: what is the actor carrying (most recent)?
    fn gen_lists(&self, n_facts: usize, rng: &mut Rng) -> Qa {
        let a = ACTORS[rng.below(ACTORS.len())];
        let mut latest = OBJECTS[rng.below(OBJECTS.len())];
        let mut sentences = vec![Self::s(&[a, "picked", "up", latest, "."])];
        for _ in 0..n_facts {
            // distractors from other actors
            let other = ACTORS[rng.below(ACTORS.len())];
            let o = OBJECTS[rng.below(OBJECTS.len())];
            if other == a {
                latest = o;
            }
            sentences.push(Self::s(&[other, "picked", "up", o, "."]));
        }
        Qa {
            sentences,
            question: Self::s(&["what", "is", &a, "carrying", "?"]),
            answer: latest.to_string(),
        }
    }

    /// family 6 — basic coreference: "he/she" refers to the last actor.
    fn gen_coreference(&self, n_facts: usize, rng: &mut Rng) -> Qa {
        let a = ACTORS[rng.below(ACTORS.len())];
        let pronoun = if matches!(a, "mary" | "sandra" | "julie") { "she" } else { "he" };
        let l1 = LOCATIONS[rng.below(LOCATIONS.len())];
        let mut sentences = vec![Self::s(&[a, "went", "to", l1, "."])];
        let mut cur = l1;
        for _ in 0..n_facts.max(1) {
            let l = LOCATIONS[rng.below(LOCATIONS.len())];
            cur = l;
            sentences.push(Self::s(&[pronoun, "went", "to", l, "."]));
        }
        Qa {
            sentences,
            question: Self::s(&["where", "is", a, "?"]),
            answer: cur.to_string(),
        }
    }

    /// family 7 — basic induction: animals of a species share a color.
    fn gen_induction(&self, n_facts: usize, rng: &mut Rng) -> Qa {
        let mut color_of: HashMap<&str, &str> = HashMap::new();
        let mut sentences = Vec::new();
        let mut exemplars: Vec<(&str, &str)> = Vec::new(); // (name=actor, species)
        for _ in 0..n_facts.max(2) {
            let species = ANIMALS[rng.below(ANIMALS.len())];
            let color = *color_of
                .entry(species)
                .or_insert_with(|| COLORS[rng.below(COLORS.len())]);
            let name = ACTORS[rng.below(ACTORS.len())];
            // "<name> is <species> . <species> is <color> ."
            sentences.push(Self::s(&[name, "is", species, "."]));
            sentences.push(Self::s(&[species, "is", color, "."]));
            exemplars.push((name, species));
        }
        let (name, species) = exemplars[rng.below(exemplars.len())];
        Qa {
            sentences,
            question: Self::s(&["what", "is", name, "?"]),
            answer: color_of[species].to_string(),
        }
    }

    fn generate(&self, family: usize, level: usize, rng: &mut Rng) -> Qa {
        let n = level.max(2);
        match family {
            0 => self.gen_one_fact(n, rng),
            1 => self.gen_two_facts(n, rng),
            2 => self.gen_three_arg(n, rng),
            3 => self.gen_yes_no(n, rng),
            4 => self.gen_counting(n, rng),
            5 => self.gen_lists(n, rng),
            6 => self.gen_coreference(n, rng),
            7 => self.gen_induction(n, rng),
            _ => unreachable!(),
        }
    }
}

impl Default for BabiTask {
    fn default() -> Self {
        Self::new()
    }
}

impl Task for BabiTask {
    fn name(&self) -> &'static str {
        "babi"
    }

    fn x_dim(&self) -> usize {
        self.vocab.len()
    }

    fn y_dim(&self) -> usize {
        self.vocab.len()
    }

    fn base_level(&self) -> usize {
        3
    }

    fn sample(&self, level: usize, rng: &mut Rng) -> Episode {
        let family = self
            .only_family
            .unwrap_or_else(|| rng.below(FAMILIES.len()));
        let qa = self.generate(family, level, rng);
        let v = self.vocab.len();
        let mut word_ids: Vec<usize> = Vec::new();
        for s in &qa.sentences {
            for w in s {
                word_ids.push(self.vocab.id(w));
            }
        }
        for w in &qa.question {
            word_ids.push(self.vocab.id(w));
        }
        let t_total = word_ids.len() + 1; // +1 answer slot
        let mut inputs = vec![vec![0.0; v]; t_total];
        let mut targets = vec![vec![0.0; v]; t_total];
        let mut mask = vec![false; t_total];
        for (t, &id) in word_ids.iter().enumerate() {
            inputs[t][id] = 1.0;
        }
        let ans = self.vocab.id(&qa.answer);
        targets[t_total - 1][ans] = 1.0;
        mask[t_total - 1] = true;
        Episode { inputs, targets, mask, loss: LossKind::Classes, family }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_is_babi_scale() {
        let v = Vocab::build();
        assert!(v.len() >= 40 && v.len() <= 160, "vocab {}", v.len());
        assert_eq!(v.word(v.id("kitchen")), "kitchen");
    }

    #[test]
    fn all_families_generate_valid_episodes() {
        let mut rng = Rng::new(1);
        for fam in 0..FAMILIES.len() {
            let task = BabiTask::family(fam);
            for _ in 0..10 {
                let ep = task.sample(4, &mut rng);
                assert_eq!(ep.family, fam);
                assert_eq!(ep.scored_steps(), 1);
                assert_eq!(ep.loss, LossKind::Classes);
                // inputs are 1-hot except the answer slot
                for t in 0..ep.len() - 1 {
                    assert_eq!(
                        ep.inputs[t].iter().filter(|&&x| x == 1.0).count(),
                        1,
                        "family {fam} step {t}"
                    );
                }
                // answer is a valid 1-hot word
                let last = &ep.targets[ep.len() - 1];
                assert_eq!(last.iter().filter(|&&x| x == 1.0).count(), 1);
            }
        }
    }

    #[test]
    fn one_fact_answer_is_latest_location() {
        let task = BabiTask::family(0);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let qa = task.gen_one_fact(5, &mut rng);
            // find queried actor
            let actor = qa.question[2].clone();
            // last sentence mentioning the actor gives the answer
            let mut latest = None;
            for s in &qa.sentences {
                if s[0] == actor {
                    latest = Some(s[3].clone());
                }
            }
            assert_eq!(latest.unwrap(), qa.answer);
        }
    }

    #[test]
    fn counting_answers_in_number_range() {
        let task = BabiTask::family(4);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let qa = task.gen_counting(6, &mut rng);
            assert!(NUMBERS.contains(&qa.answer.as_str()));
        }
    }

    #[test]
    fn yes_no_balanced_enough() {
        let task = BabiTask::family(3);
        let mut rng = Rng::new(4);
        let mut yes = 0;
        for _ in 0..200 {
            let qa = task.gen_yes_no(3, &mut rng);
            if qa.answer == "yes" {
                yes += 1;
            }
        }
        assert!((40..=160).contains(&yes), "yes={yes}/200");
    }

    #[test]
    fn joint_sampling_covers_families() {
        let task = BabiTask::new();
        let mut rng = Rng::new(5);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(task.sample(3, &mut rng).family);
        }
        assert_eq!(seen.len(), FAMILIES.len());
    }
}
