//! The paper's task suite (§4): NTM algorithmic tasks (copy, associative
//! recall, priority sort), Omniglot-style one-shot classification, and a
//! synthetic Babi-style reasoning suite. Every task generates episodes at a
//! parameterized difficulty `level` for the exponential curriculum (§4.3).

pub mod babi;
pub mod copy;
pub mod omniglot;
pub mod recall;
pub mod sort;

use crate::util::rng::Rng;

/// How episode targets are scored / differentiated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Independent sigmoid cross-entropy per output bit (algorithmic tasks).
    Bits,
    /// Softmax cross-entropy over classes; targets are one-hot (Omniglot, Babi).
    Classes,
}

/// One training episode: aligned input/target sequences and a mask marking
/// the steps where loss (and error metrics) apply.
#[derive(Debug, Clone)]
pub struct Episode {
    pub inputs: Vec<Vec<f32>>,
    pub targets: Vec<Vec<f32>>,
    pub mask: Vec<bool>,
    pub loss: LossKind,
    /// Optional per-step annotation for diagnostics (e.g. Babi task family).
    pub family: usize,
}

impl Episode {
    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    /// Count scored steps.
    pub fn scored_steps(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }
}

/// An episodic task with a difficulty knob.
pub trait Task: Send + Sync {
    fn name(&self) -> &'static str;
    fn x_dim(&self) -> usize;
    fn y_dim(&self) -> usize;
    /// Sample an episode at the given difficulty level (≥ 1).
    fn sample(&self, level: usize, rng: &mut Rng) -> Episode;
    /// The level the curriculum starts at.
    fn base_level(&self) -> usize {
        1
    }
    /// Task-relevant error count for an episode given model outputs
    /// (bits wrong for bit tasks, misclassifications for class tasks).
    fn errors(&self, ep: &Episode, outputs: &[Vec<f32>]) -> f64 {
        default_errors(ep, outputs)
    }
}

/// Default error metric: bit errors or argmax mismatches on masked steps.
pub fn default_errors(ep: &Episode, outputs: &[Vec<f32>]) -> f64 {
    let mut errs = 0.0;
    for t in 0..ep.len() {
        if !ep.mask[t] {
            continue;
        }
        match ep.loss {
            LossKind::Bits => {
                errs += crate::nn::loss::bit_errors(&outputs[t], &ep.targets[t]) as f64;
            }
            LossKind::Classes => {
                let pred = crate::nn::loss::argmax(&outputs[t]);
                let want = crate::nn::loss::argmax(&ep.targets[t]);
                if pred != want {
                    errs += 1.0;
                }
            }
        }
    }
    errs
}

/// Per-episode loss + gradient helper shared by the trainer and benches.
pub fn episode_loss_grad(ep: &Episode, t: usize, y: &[f32]) -> (f32, Vec<f32>) {
    if !ep.mask[t] {
        return (0.0, vec![0.0; y.len()]);
    }
    match ep.loss {
        LossKind::Bits => crate::nn::loss::sigmoid_xent(y, &ep.targets[t]),
        LossKind::Classes => {
            let target = crate::nn::loss::argmax(&ep.targets[t]);
            crate::nn::loss::softmax_xent(y, target)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_errors_bits() {
        let ep = Episode {
            inputs: vec![vec![0.0; 2]; 2],
            targets: vec![vec![1.0, 0.0], vec![0.0, 1.0]],
            mask: vec![true, false],
            loss: LossKind::Bits,
            family: 0,
        };
        let outs = vec![vec![-1.0, -1.0], vec![9.0, 9.0]];
        // step0 scored: predicted (0,0) vs target (1,0) -> 1 bit wrong.
        assert_eq!(default_errors(&ep, &outs), 1.0);
    }

    #[test]
    fn loss_grad_masked_is_zero() {
        let ep = Episode {
            inputs: vec![vec![0.0; 2]],
            targets: vec![vec![1.0, 0.0]],
            mask: vec![false],
            loss: LossKind::Bits,
            family: 0,
        };
        let (l, g) = episode_loss_grad(&ep, 0, &[0.3, -0.2]);
        assert_eq!(l, 0.0);
        assert!(g.iter().all(|&x| x == 0.0));
    }
}
