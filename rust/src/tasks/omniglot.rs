//! Omniglot-style one-shot classification (paper §4.5, Fig 4), following
//! Santoro et al. 2016: at each step the model sees a character example
//! together with the *previous* step's correct label, and must emit the
//! current example's label. Labels are randomly assigned per episode, so
//! the model must bind example→label in memory on first presentation.
//!
//! **Substitution** (no Omniglot images offline, documented in DESIGN.md):
//! a "character class" is a random unit prototype vector; an "example" of
//! it is the prototype passed through a random per-example affine
//! distortion (scaling + rotation in random 2-D subspaces) plus noise —
//! mirroring the paper's rotate/stretch augmentation in embedding space.
//! The memory system consumes an embedding either way; the one-shot
//! recall structure is identical.
//!
//! Level = number of character classes in the episode; each class appears
//! `presentations` times (paper: 10).

use super::{Episode, LossKind, Task};
use crate::util::rng::Rng;

pub struct OmniglotTask {
    /// Embedding dimension of a "character image".
    pub embed_dim: usize,
    /// Output label space (max classes per episode).
    pub max_classes: usize,
    /// Times each class appears per episode (paper: 10).
    pub presentations: usize,
    /// Per-example distortion noise.
    pub noise: f32,
}

impl OmniglotTask {
    pub fn new(embed_dim: usize, max_classes: usize) -> OmniglotTask {
        OmniglotTask { embed_dim, max_classes, presentations: 10, noise: 0.15 }
    }

    fn prototype(&self, rng: &mut Rng) -> Vec<f32> {
        let mut v: Vec<f32> = (0..self.embed_dim).map(|_| rng.normal()).collect();
        let n = crate::tensor::matrix::norm(&v).max(1e-6);
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    /// Distort a prototype: random 2-D rotation + scale + additive noise.
    fn example_of(&self, proto: &[f32], rng: &mut Rng) -> Vec<f32> {
        let mut v = proto.to_vec();
        // a few random planar rotations ("rotate")
        for _ in 0..3 {
            let i = rng.below(self.embed_dim);
            let j = rng.below(self.embed_dim);
            if i == j {
                continue;
            }
            let theta = rng.uniform_in(-0.4, 0.4);
            let (s, c) = theta.sin_cos();
            let (vi, vj) = (v[i], v[j]);
            v[i] = c * vi - s * vj;
            v[j] = s * vi + c * vj;
        }
        // per-example scale ("stretch") and noise
        let scale = rng.uniform_in(0.8, 1.2);
        for x in v.iter_mut() {
            *x = *x * scale + self.noise * rng.normal();
        }
        v
    }
}

impl Task for OmniglotTask {
    fn name(&self) -> &'static str {
        "omniglot"
    }

    fn x_dim(&self) -> usize {
        self.embed_dim + self.max_classes
    }

    fn y_dim(&self) -> usize {
        self.max_classes
    }

    fn base_level(&self) -> usize {
        3
    }

    fn sample(&self, level: usize, rng: &mut Rng) -> Episode {
        let classes = level.clamp(2, self.max_classes);
        let protos: Vec<Vec<f32>> = (0..classes).map(|_| self.prototype(rng)).collect();
        // Random label assignment per episode (the one-shot twist).
        let mut labels: Vec<usize> = (0..self.max_classes).collect();
        rng.shuffle(&mut labels);
        let labels = &labels[..classes];

        // presentation order: each class `presentations` times, shuffled.
        let mut order: Vec<usize> = (0..classes)
            .flat_map(|c| std::iter::repeat(c).take(self.presentations))
            .collect();
        rng.shuffle(&mut order);

        let t_total = order.len();
        let x_dim = self.x_dim();
        let mut inputs = vec![vec![0.0; x_dim]; t_total];
        let mut targets = vec![vec![0.0; self.max_classes]; t_total];
        let mut mask = vec![false; t_total];
        let mut prev_label: Option<usize> = None;
        for (t, &c) in order.iter().enumerate() {
            let ex = self.example_of(&protos[c], rng);
            inputs[t][..self.embed_dim].copy_from_slice(&ex);
            if let Some(pl) = prev_label {
                inputs[t][self.embed_dim + pl] = 1.0;
            }
            targets[t][labels[c]] = 1.0;
            mask[t] = true;
            prev_label = Some(labels[c]);
        }
        Episode { inputs, targets, mask, loss: LossKind::Classes, family: 0 }
    }

    /// Fraction of wrong predictions on presentations ≥ 2 of each class
    /// (the first sighting is unguessable; the paper's errors-per-episode
    /// metric likewise reflects post-first-presentation recall).
    fn errors(&self, ep: &Episode, outputs: &[Vec<f32>]) -> f64 {
        let mut seen = std::collections::HashSet::new();
        let mut errs = 0.0;
        let mut scored = 0.0;
        for t in 0..ep.len() {
            let want = crate::nn::loss::argmax(&ep.targets[t]);
            if seen.insert(want) {
                continue; // first presentation
            }
            scored += 1.0;
            if crate::nn::loss::argmax(&outputs[t]) != want {
                errs += 1.0;
            }
        }
        if scored > 0.0 {
            errs / scored
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matrix::cosine;

    #[test]
    fn episode_structure() {
        let task = OmniglotTask::new(16, 8);
        let mut rng = Rng::new(1);
        let ep = task.sample(5, &mut rng);
        assert_eq!(ep.len(), 5 * 10);
        assert!(ep.mask.iter().all(|&m| m));
        assert_eq!(ep.loss, LossKind::Classes);
        // each target is one-hot
        for t in &ep.targets {
            assert_eq!(t.iter().filter(|&&x| x == 1.0).count(), 1);
        }
    }

    #[test]
    fn examples_cluster_by_class() {
        let task = OmniglotTask::new(32, 4);
        let mut rng = Rng::new(2);
        let p1 = task.prototype(&mut rng);
        let p2 = task.prototype(&mut rng);
        let e1a = task.example_of(&p1, &mut rng);
        let e1b = task.example_of(&p1, &mut rng);
        let e2 = task.example_of(&p2, &mut rng);
        let same = cosine(&e1a, &e1b, 1e-6);
        let diff = cosine(&e1a, &e2, 1e-6);
        assert!(same > diff + 0.2, "same={same} diff={diff}");
    }

    #[test]
    fn prev_label_channel_lags_by_one() {
        let task = OmniglotTask::new(8, 6);
        let mut rng = Rng::new(3);
        let ep = task.sample(3, &mut rng);
        for t in 1..ep.len() {
            let prev_target = crate::nn::loss::argmax(&ep.targets[t - 1]);
            let chan: Vec<f32> = ep.inputs[t][8..].to_vec();
            assert_eq!(crate::nn::loss::argmax(&chan), prev_target);
            assert_eq!(chan.iter().sum::<f32>(), 1.0);
        }
        // first step has no previous label
        assert!(ep.inputs[0][8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn error_metric_skips_first_presentations() {
        let task = OmniglotTask::new(8, 4);
        let mut rng = Rng::new(4);
        let ep = task.sample(2, &mut rng);
        // Perfect outputs -> zero error.
        let outs: Vec<Vec<f32>> = ep.targets.clone();
        assert_eq!(task.errors(&ep, &outs), 0.0);
        // All-wrong outputs -> error 1.0 (on scored steps).
        let bad: Vec<Vec<f32>> = ep
            .targets
            .iter()
            .map(|t| {
                let mut v = vec![0.0; t.len()];
                let w = crate::nn::loss::argmax(t);
                v[(w + 1) % t.len()] = 1.0;
                v
            })
            .collect();
        assert_eq!(task.errors(&ep, &bad), 1.0);
    }
}
