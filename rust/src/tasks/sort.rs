//! Priority sort (paper §4.2, task 3; from the NTM paper): given random
//! keys with scalar priorities, return the top ⌈4/5·n⌉ keys in descending
//! priority order. Level = number of input items (paper base: 20 in / 16 out).
//!
//! Input layout: [bits…, priority, input flag, delimiter flag].

use super::{Episode, LossKind, Task};
use crate::util::rng::Rng;

pub struct PrioritySort {
    pub bits: usize,
}

impl PrioritySort {
    pub fn new(bits: usize) -> PrioritySort {
        PrioritySort { bits }
    }
}

impl Task for PrioritySort {
    fn name(&self) -> &'static str {
        "sort"
    }

    fn x_dim(&self) -> usize {
        self.bits + 3
    }

    fn y_dim(&self) -> usize {
        self.bits
    }

    fn base_level(&self) -> usize {
        20
    }

    fn sample(&self, level: usize, rng: &mut Rng) -> Episode {
        let n_in = level.max(2);
        let n_out = ((4 * n_in) / 5).max(1);
        let x_dim = self.x_dim();
        let t_total = n_in + 1 + n_out;
        let mut inputs = vec![vec![0.0; x_dim]; t_total];
        let mut targets = vec![vec![0.0; self.bits]; t_total];
        let mut mask = vec![false; t_total];

        let mut items: Vec<(f32, Vec<f32>)> = (0..n_in)
            .map(|_| {
                let word: Vec<f32> =
                    (0..self.bits).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
                (rng.uniform_in(-1.0, 1.0), word)
            })
            .collect();
        for (t, (prio, word)) in items.iter().enumerate() {
            inputs[t][..self.bits].copy_from_slice(word);
            inputs[t][self.bits] = *prio;
            inputs[t][self.bits + 1] = 1.0; // input flag
        }
        inputs[n_in][self.bits + 2] = 1.0; // delimiter
        items.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for i in 0..n_out {
            let t = n_in + 1 + i;
            targets[t].copy_from_slice(&items[i].1);
            mask[t] = true;
        }
        Episode { inputs, targets, mask, loss: LossKind::Bits, family: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_are_sorted_inputs() {
        let task = PrioritySort::new(5);
        let mut rng = Rng::new(1);
        let ep = task.sample(10, &mut rng);
        let n_in = 10;
        let n_out = 8;
        assert_eq!(ep.len(), n_in + 1 + n_out);
        // reconstruct priorities and verify target order is descending
        let mut pairs: Vec<(f32, Vec<f32>)> = (0..n_in)
            .map(|t| (ep.inputs[t][5], ep.inputs[t][..5].to_vec()))
            .collect();
        pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for i in 0..n_out {
            assert_eq!(&ep.targets[n_in + 1 + i][..], &pairs[i].1[..], "rank {i}");
        }
        assert_eq!(ep.scored_steps(), n_out);
    }

    #[test]
    fn paper_default_is_20_to_16() {
        let task = PrioritySort::new(6);
        let mut rng = Rng::new(2);
        let ep = task.sample(task.base_level(), &mut rng);
        assert_eq!(ep.len(), 20 + 1 + 16);
    }
}
