//! Associative recall (paper §4.2, task 2): present (key, value) pairs,
//! then cue with one key; the model must return the associated value.
//! Level = number of pairs stored (the paper's curriculum pushes this past
//! 4000 pairs ⇒ episodes of thousands of steps, Fig 3a / Fig 8).
//!
//! Input layout: [bits…, key flag, value flag, query flag].

use super::{Episode, LossKind, Task};
use crate::util::rng::Rng;

pub struct AssociativeRecall {
    pub bits: usize,
}

impl AssociativeRecall {
    /// Paper base setup: 3-6 pairs of 6-bit words.
    pub fn new(bits: usize) -> AssociativeRecall {
        AssociativeRecall { bits }
    }

    fn rand_word(&self, rng: &mut Rng) -> Vec<f32> {
        (0..self.bits).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect()
    }
}

impl Task for AssociativeRecall {
    fn name(&self) -> &'static str {
        "recall"
    }

    fn x_dim(&self) -> usize {
        self.bits + 3
    }

    fn y_dim(&self) -> usize {
        self.bits
    }

    fn base_level(&self) -> usize {
        6
    }

    fn sample(&self, level: usize, rng: &mut Rng) -> Episode {
        let pairs = rng.int_in(1.max(level.min(3)), level.max(3));
        let x_dim = self.x_dim();
        let t_total = 2 * pairs + 2;
        let mut inputs = vec![vec![0.0; x_dim]; t_total];
        let mut targets = vec![vec![0.0; self.bits]; t_total];
        let mut mask = vec![false; t_total];

        // Distinct keys so the answer is unambiguous.
        let mut keys: Vec<Vec<f32>> = Vec::with_capacity(pairs);
        while keys.len() < pairs {
            let k = self.rand_word(rng);
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let values: Vec<Vec<f32>> = (0..pairs).map(|_| self.rand_word(rng)).collect();

        for i in 0..pairs {
            inputs[2 * i][..self.bits].copy_from_slice(&keys[i]);
            inputs[2 * i][self.bits] = 1.0; // key flag
            inputs[2 * i + 1][..self.bits].copy_from_slice(&values[i]);
            inputs[2 * i + 1][self.bits + 1] = 1.0; // value flag
        }
        let q = rng.below(pairs);
        let tq = 2 * pairs;
        inputs[tq][..self.bits].copy_from_slice(&keys[q]);
        inputs[tq][self.bits + 2] = 1.0; // query flag
        targets[tq + 1].copy_from_slice(&values[q]);
        mask[tq + 1] = true;
        Episode { inputs, targets, mask, loss: LossKind::Bits, family: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_key_matches_a_stored_pair() {
        let task = AssociativeRecall::new(6);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let ep = task.sample(5, &mut rng);
            let pairs = (ep.len() - 2) / 2;
            let tq = 2 * pairs;
            assert_eq!(ep.inputs[tq][6 + 2], 1.0, "query flag");
            // find the queried key among stored keys
            let qkey = &ep.inputs[tq][..6];
            let mut found = None;
            for i in 0..pairs {
                if &ep.inputs[2 * i][..6] == qkey {
                    found = Some(i);
                }
            }
            let i = found.expect("query key must be stored");
            assert_eq!(&ep.inputs[2 * i + 1][..6], &ep.targets[tq + 1][..]);
            assert_eq!(ep.scored_steps(), 1);
        }
    }

    #[test]
    fn level_scales_pairs() {
        let task = AssociativeRecall::new(6);
        let mut rng = Rng::new(2);
        let ep = task.sample(50, &mut rng);
        assert!(ep.len() >= 2 * 3 + 2);
        assert!(ep.len() <= 2 * 50 + 2);
    }
}
