//! Copy task (paper §4.2, task 1; from the NTM paper): emit a verbatim copy
//! of a random binary sequence after a delimiter. Level = sequence length.
//!
//! Input layout: [bits… , write-phase flag, delimiter flag].
//! During the recall phase inputs are zero and targets carry the bits.

use super::{Episode, LossKind, Task};
use crate::util::rng::Rng;

pub struct CopyTask {
    pub bits: usize,
}

impl CopyTask {
    /// Paper setup: 6-bit words, lengths 1-20 at base difficulty.
    pub fn new(bits: usize) -> CopyTask {
        CopyTask { bits }
    }
}

impl Task for CopyTask {
    fn name(&self) -> &'static str {
        "copy"
    }

    fn x_dim(&self) -> usize {
        self.bits + 2
    }

    fn y_dim(&self) -> usize {
        self.bits
    }

    fn base_level(&self) -> usize {
        // The paper trains on lengths 1..20 before the curriculum scales.
        20
    }

    fn sample(&self, level: usize, rng: &mut Rng) -> Episode {
        let len = rng.int_in(1, level.max(1));
        let x_dim = self.x_dim();
        let t_total = 2 * len + 1;
        let mut inputs = vec![vec![0.0; x_dim]; t_total];
        let mut targets = vec![vec![0.0; self.bits]; t_total];
        let mut mask = vec![false; t_total];
        let payload: Vec<Vec<f32>> = (0..len)
            .map(|_| (0..self.bits).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
            .collect();
        for (t, word) in payload.iter().enumerate() {
            inputs[t][..self.bits].copy_from_slice(word);
            inputs[t][self.bits] = 1.0; // write phase
        }
        inputs[len][self.bits + 1] = 1.0; // delimiter
        for (i, word) in payload.iter().enumerate() {
            let t = len + 1 + i;
            targets[t].copy_from_slice(word);
            mask[t] = true;
        }
        Episode { inputs, targets, mask, loss: LossKind::Bits, family: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_is_write_delim_recall() {
        let task = CopyTask::new(6);
        let mut rng = Rng::new(1);
        let ep = task.sample(10, &mut rng);
        let len = (ep.len() - 1) / 2;
        assert!(len >= 1 && len <= 10);
        assert_eq!(ep.len(), 2 * len + 1);
        // delimiter at position len
        assert_eq!(ep.inputs[len][7], 1.0);
        // recall phase inputs are zero, targets masked on
        for t in len + 1..ep.len() {
            assert!(ep.inputs[t].iter().all(|&x| x == 0.0));
            assert!(ep.mask[t]);
        }
        assert_eq!(ep.scored_steps(), len);
    }

    #[test]
    fn target_equals_payload() {
        let task = CopyTask::new(4);
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let ep = task.sample(8, &mut rng);
            let len = (ep.len() - 1) / 2;
            for i in 0..len {
                let input_bits = &ep.inputs[i][..4];
                let target_bits = &ep.targets[len + 1 + i][..];
                assert_eq!(input_bits, target_bits);
            }
        }
    }

    #[test]
    fn level_bounds_length() {
        let task = CopyTask::new(6);
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let ep = task.sample(3, &mut rng);
            assert!(ep.len() <= 7);
        }
    }
}
