//! The zero-allocation steady-state guarantee: after warm-up episodes have
//! populated the workspace pools, a SAM or SDNC step — `forward_into` +
//! `backward` — performs **zero** heap allocations.
//!
//! Measurement uses the per-thread allocation-event counter in
//! `util::alloc` (the process-wide counters are polluted by concurrently
//! running tests), diffed around each core call so the loss computation
//! between steps stays out of scope.
//!
//! The same runs double as a numerics guard: buffer recycling must not
//! perturb a single output bit relative to the first (cold, allocating)
//! episode.

use sam::nn::loss::sigmoid_xent;
use sam::prelude::*;
use sam::util::alloc::thread_alloc_count;

/// Episodes to run before measuring. The pools converge after one episode
/// for stack-disciplined buffers; a few extra cover tape-held buffers that
/// permute through the pools before every one has grown to its largest
/// role.
const WARMUP_EPISODES: usize = 4;

fn run_core(mut core: Box<dyn Core>, x_dim: usize, y_dim: usize, label: &str) {
    let mut rng = Rng::new(1234);
    let t_len = 8;
    let xs: Vec<Vec<f32>> = (0..t_len)
        .map(|_| (0..x_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
        .collect();
    let ts: Vec<Vec<f32>> = (0..t_len)
        .map(|_| (0..y_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
        .collect();

    // Long-lived across episodes: the output buffer and dy staging reach
    // steady capacity during warm-up like everything else.
    let mut y: Vec<f32> = Vec::new();
    let mut dys: Vec<Vec<f32>> = Vec::new();
    let mut first_bits: Vec<Vec<u32>> = Vec::new();

    for ep in 0..=WARMUP_EPISODES {
        core.zero_grads();
        core.reset();
        dys.clear();
        let mut allocs = 0usize;
        let mut bits: Vec<Vec<u32>> = Vec::new();
        for (x, t) in xs.iter().zip(&ts) {
            let before = thread_alloc_count();
            core.forward_into(x, &mut y);
            allocs += thread_alloc_count() - before;
            bits.push(y.iter().map(|v| v.to_bits()).collect());
            dys.push(sigmoid_xent(&y, t).1);
        }
        for dy in dys.iter().rev() {
            let before = thread_alloc_count();
            core.backward(dy);
            allocs += thread_alloc_count() - before;
        }
        core.end_episode();
        if ep == 0 {
            first_bits = bits;
        } else {
            assert_eq!(
                first_bits, bits,
                "{label}: buffer recycling changed outputs in episode {ep}"
            );
        }
        if ep == WARMUP_EPISODES {
            assert_eq!(
                allocs, 0,
                "{label}: steady-state episode performed {allocs} allocations \
                 across {t_len} forward_into + {t_len} backward calls"
            );
        }
    }
}

fn cfg(x_dim: usize, y_dim: usize) -> CoreConfig {
    CoreConfig {
        x_dim,
        y_dim,
        hidden: 16,
        heads: 2,
        word: 8,
        mem_words: 64,
        k: 3,
        k_l: 4,
        ann: AnnKind::Linear,
        seed: 77,
        ..CoreConfig::default()
    }
}

#[test]
fn sam_steps_allocate_nothing_after_warmup() {
    let mut rng = Rng::new(7);
    let core = build_core(CoreKind::Sam, &cfg(5, 4), &mut rng);
    run_core(core, 5, 4, "sam");
}

#[test]
fn sdnc_steps_allocate_nothing_after_warmup() {
    let mut rng = Rng::new(8);
    let core = build_core(CoreKind::Sdnc, &cfg(5, 4), &mut rng);
    run_core(core, 5, 4, "sdnc");
}

#[test]
fn sam_infer_steps_allocate_nothing_after_warmup() {
    // The serving acceptance criterion: a forward-only SAM step performs
    // ZERO journal/tape allocations — in fact zero allocations at all —
    // and the session's tape stays at 0 bytes throughout. Warm-up works
    // like training: one episode populates the pools.
    use sam::cores::sam::SamCore;

    let c = cfg(5, 4);
    let mut rng = Rng::new(7);
    let core = SamCore::new(&c, &mut rng);
    let mut session = core.infer_session(None);
    let t_len = 8;
    let mut xrng = Rng::new(1234);
    let xs: Vec<Vec<f32>> = (0..t_len)
        .map(|_| (0..5).map(|_| if xrng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
        .collect();
    let mut y: Vec<f32> = Vec::new();
    let mut first_bits: Vec<Vec<u32>> = Vec::new();
    for ep in 0..=WARMUP_EPISODES {
        session.reset();
        let mut allocs = 0usize;
        let mut bits: Vec<Vec<u32>> = Vec::new();
        for x in &xs {
            let before = thread_alloc_count();
            core.infer_step(&mut session, x, &mut y);
            allocs += thread_alloc_count() - before;
            assert_eq!(session.tape_bytes(), 0, "infer step grew a tape");
            bits.push(y.iter().map(|v| v.to_bits()).collect());
        }
        if ep == 0 {
            first_bits = bits;
        } else {
            assert_eq!(first_bits, bits, "session reset/recycling changed outputs in ep {ep}");
        }
        if ep == WARMUP_EPISODES {
            assert_eq!(
                allocs, 0,
                "steady-state serving episode performed {allocs} allocations \
                 across {t_len} infer_step calls"
            );
        }
    }
}

#[test]
fn sam_infer_steps_with_compact_rows_allocate_nothing_after_warmup() {
    // Compact-row twin of the serving guarantee: with bf16 storage, the
    // decode-fused read path, the quantize-on-write path and the ANN sync
    // (which stages decoded rows in a persistent scratch) must all stay
    // allocation-free in steady state — whatever kernel dispatch is active.
    use sam::cores::sam::SamCore;
    use sam::tensor::rowcodec::RowFormat;

    let c = CoreConfig { row_format: RowFormat::Bf16, ..cfg(5, 4) };
    let mut rng = Rng::new(7);
    let core = SamCore::new(&c, &mut rng);
    let mut session = core.infer_session(None);
    let t_len = 8;
    let mut xrng = Rng::new(1234);
    let xs: Vec<Vec<f32>> = (0..t_len)
        .map(|_| (0..5).map(|_| if xrng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
        .collect();
    let mut y: Vec<f32> = Vec::new();
    let mut first_bits: Vec<Vec<u32>> = Vec::new();
    for ep in 0..=WARMUP_EPISODES {
        session.reset();
        let mut allocs = 0usize;
        let mut bits: Vec<Vec<u32>> = Vec::new();
        for x in &xs {
            let before = thread_alloc_count();
            core.infer_step(&mut session, x, &mut y);
            allocs += thread_alloc_count() - before;
            assert_eq!(session.tape_bytes(), 0, "compact infer step grew a tape");
            bits.push(y.iter().map(|v| v.to_bits()).collect());
        }
        if ep == 0 {
            first_bits = bits;
        } else {
            assert_eq!(first_bits, bits, "compact session recycling changed outputs in ep {ep}");
        }
        if ep == WARMUP_EPISODES {
            assert_eq!(
                allocs, 0,
                "steady-state bf16-row serving episode performed {allocs} allocations \
                 across {t_len} infer_step calls"
            );
        }
    }
}

#[test]
fn sam_sharded_steps_allocate_nothing_after_warmup() {
    // The sharded tentpole's steady-state guarantee at S=4 (or CI's
    // SAM_TEST_SHARDS): the global write split, the per-shard journals and
    // the per-head merge buffers must all recycle — zero allocations per
    // step after warm-up, bit-stable episode over episode.
    let s = sam::util::env_shards().unwrap_or(4);
    let mut rng = Rng::new(7);
    let c = CoreConfig { shards: s, ..cfg(5, 4) };
    let core = build_core(CoreKind::Sam, &c, &mut rng);
    run_core(core, 5, 4, "sam-sharded");
}

#[test]
fn sdnc_sharded_steps_allocate_nothing_after_warmup() {
    let s = sam::util::env_shards().unwrap_or(4);
    let mut rng = Rng::new(8);
    let c = CoreConfig { shards: s, ..cfg(5, 4) };
    let core = build_core(CoreKind::Sdnc, &c, &mut rng);
    run_core(core, 5, 4, "sdnc-sharded");
}

#[test]
fn sharded_parallel_query_dispatch_allocates_nothing_after_warmup() {
    // Above SHARD_PARALLEL_MIN_ROWS the fan-out goes through the global
    // ShardPool; the dispatch itself (thread-local batch, queue pushes,
    // merge) must be allocation-free on the calling thread in steady
    // state. Engine-level, N past the threshold, S=4.
    use sam::memory::sharded::{ShardedMemoryEngine, SHARD_PARALLEL_MIN_ROWS};
    use sam::tensor::csr::SparseVec;
    use sam::tensor::workspace::Workspace;

    let n = SHARD_PARALLEL_MIN_ROWS * 2;
    let word = 16;
    let mut rng = Rng::new(17);
    let mut e = ShardedMemoryEngine::new_sparse(n, word, 4, 0.005, AnnKind::Linear, &mut rng, 4);
    let mut ws = Workspace::new();
    let queries: Vec<Vec<f32>> = (0..2)
        .map(|h| (0..word).map(|j| ((h + j) as f32).sin()).collect())
        .collect();
    let betas = vec![0.4f32; 2];
    let word_v: Vec<f32> = vec![0.25; word];
    let empty = SparseVec::new();
    let mut out: Vec<sam::memory::engine::TopKRead> = Vec::new();
    // The serving-shaped step: journal-free write + batched sharded read —
    // the write keeps shard contents (and thus ANN sync work) moving while
    // the read exercises the pool dispatch and the merge.
    macro_rules! step {
        () => {{
            let wts = e.infer_write(0.3, -0.2, &empty, &word_v, &mut ws);
            ws.recycle_sparse(wts);
            e.read_topk_into(&queries, &betas, &mut out, &mut ws);
            for tk in out.drain(..) {
                ws.recycle_sparse(tk.weights);
                ws.recycle_f32(tk.r);
                e.recycle_content_read(tk.read, &mut ws);
            }
        }};
    }
    // Warm up pools, the thread-local pool batch and the queue capacity.
    for _ in 0..8 {
        step!();
    }
    let before = thread_alloc_count();
    for _ in 0..8 {
        step!();
    }
    let allocs = thread_alloc_count() - before;
    assert_eq!(
        allocs, 0,
        "steady-state sharded parallel query performed {allocs} caller-side allocations"
    );
    assert_eq!(e.tape_bytes(), 0);
}

fn run_batched_ticks<C: sam::cores::BatchCore>(mut lanes: Vec<C>, y_dim: usize, label: &str) {
    // The batched-training twin of `run_core`: after warm-up, a full
    // B-lane training tick — `train_tick_forward` + `train_tick_backward`
    // — allocates nothing. The `TrainBatch` gather/scatter matrices, every
    // lane's tape/journal pools and the merged ANN staging all converge
    // during warm-up; dY staging and the loss computation sit outside the
    // measured window exactly like the loss in `run_core`.
    use sam::cores::{train_tick_backward, train_tick_forward, TrainBatch};

    let b = lanes.len();
    let x_dim = lanes[0].x_dim();
    let t_len = 8;
    let mut rng = Rng::new(1234);
    let xs: Vec<Vec<Vec<f32>>> = (0..t_len)
        .map(|_| {
            (0..b)
                .map(|_| (0..x_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
                .collect()
        })
        .collect();
    let ts: Vec<Vec<Vec<f32>>> = (0..t_len)
        .map(|_| {
            (0..b)
                .map(|_| (0..y_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
                .collect()
        })
        .collect();

    let mut batch = TrainBatch::new();
    let active = vec![true; b];
    let mut lane_refs: Vec<Option<&[f32]>>;
    let mut dys: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut first_bits: Vec<Vec<u32>> = Vec::new();

    for ep in 0..=WARMUP_EPISODES {
        for lane in lanes.iter_mut() {
            lane.zero_grads();
            lane.reset();
        }
        dys.clear();
        let mut allocs = 0usize;
        let mut bits: Vec<Vec<u32>> = Vec::new();
        for t in 0..t_len {
            lane_refs = xs[t].iter().map(|x| Some(x.as_slice())).collect();
            let before = thread_alloc_count();
            train_tick_forward(&mut lanes, &mut batch, &lane_refs);
            allocs += thread_alloc_count() - before;
            let mut step_dys = Vec::new();
            for l in 0..b {
                bits.push(batch.y_row(l).iter().map(|v| v.to_bits()).collect());
                step_dys.push(sigmoid_xent(batch.y_row(l), &ts[t][l]).1);
            }
            dys.push(step_dys);
        }
        for t in (0..t_len).rev() {
            batch.stage_dy(b, y_dim);
            for l in 0..b {
                batch.dy_row_mut(l).copy_from_slice(&dys[t][l]);
            }
            let before = thread_alloc_count();
            train_tick_backward(&mut lanes, &mut batch, &active);
            allocs += thread_alloc_count() - before;
        }
        for lane in lanes.iter_mut() {
            lane.end_episode();
        }
        if ep == 0 {
            first_bits = bits;
        } else {
            assert_eq!(
                first_bits, bits,
                "{label}: batch-buffer recycling changed outputs in episode {ep}"
            );
        }
        if ep == WARMUP_EPISODES {
            assert_eq!(
                allocs, 0,
                "{label}: steady-state batched episode performed {allocs} allocations \
                 across {t_len} forward + {t_len} backward ticks over {b} lanes"
            );
        }
    }
}

#[test]
fn sam_batched_ticks_allocate_nothing_after_warmup() {
    use sam::cores::sam::SamCore;
    let b = sam::util::env_batch().unwrap_or(4);
    let c = cfg(5, 4);
    let lanes: Vec<SamCore> = (0..b).map(|_| SamCore::new(&c, &mut Rng::new(7))).collect();
    run_batched_ticks(lanes, 4, "sam-batched");
}

#[test]
fn sdnc_batched_ticks_allocate_nothing_after_warmup() {
    use sam::cores::sdnc::SdncCore;
    let b = sam::util::env_batch().unwrap_or(4);
    let c = cfg(5, 4);
    let lanes: Vec<SdncCore> = (0..b).map(|_| SdncCore::new(&c, &mut Rng::new(8))).collect();
    run_batched_ticks(lanes, 4, "sdnc-batched");
}

#[test]
fn serving_manager_step_with_metrics_allocates_nothing_after_warmup() {
    // The observability contract at the serving layer: a steady-state
    // `SessionManager::step` — which now stamps SERVE_STEPS and the step
    // latency histogram on every call — still performs zero caller-side
    // heap allocations. Counters are relaxed atomics and the histogram is
    // fixed buckets, so instrumentation must be invisible to the allocator.
    use sam::serving::{build_infer_model, SessionConfig, SessionManager};
    use sam::util::metrics;

    let c = cfg(5, 4);
    let mut rng = Rng::new(7);
    let model = build_infer_model(CoreKind::Sam, &c, &mut rng, None);
    let mgr = SessionManager::new(model, SessionConfig::default());
    let id = mgr.open_seeded(None);
    let t_len = 8;
    let mut xrng = Rng::new(1234);
    let xs: Vec<Vec<f32>> = (0..t_len)
        .map(|_| (0..5).map(|_| if xrng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
        .collect();
    let mut y: Vec<f32> = Vec::new();
    // Warm-up: pools, the session's state buffers and `y` reach capacity.
    for _ in 0..WARMUP_EPISODES {
        for x in &xs {
            mgr.step(id, x, &mut y).unwrap();
        }
        mgr.reset(id).unwrap();
    }
    let steps_before = metrics::SERVE_STEPS.get();
    let hist_before = metrics::SERVE_STEP_LATENCY_US.count();
    let before = thread_alloc_count();
    for x in &xs {
        mgr.step(id, x, &mut y).unwrap();
    }
    let allocs = thread_alloc_count() - before;
    assert_eq!(
        allocs, 0,
        "steady-state manager step with metrics performed {allocs} allocations \
         across {t_len} steps"
    );
    // The registry is process-global (parallel tests may also bump it), so
    // assert the delta floor, not equality.
    assert!(
        metrics::SERVE_STEPS.get() >= steps_before + t_len as u64,
        "SERVE_STEPS did not advance across the measured steps"
    );
    assert!(
        metrics::SERVE_STEP_LATENCY_US.count() >= hist_before + t_len as u64,
        "step-latency histogram did not record the measured steps"
    );
}

#[test]
fn train_tick_metrics_advance_during_zero_alloc_ticks() {
    // Companion to the batched-tick legs: the per-phase timers live inside
    // the measured window of `run_batched_ticks`, so this checks they are
    // actually firing — a tick bumps TRAIN_TICKS and lands one observation
    // in every forward-phase histogram.
    use sam::cores::sam::SamCore;
    use sam::util::metrics;

    let ticks_before = metrics::TRAIN_TICKS.get();
    let phase_before: Vec<u64> =
        metrics::TRAIN_FWD_PHASE_US.iter().map(|h| h.count()).collect();
    let c = cfg(5, 4);
    let lanes: Vec<SamCore> = (0..2).map(|_| SamCore::new(&c, &mut Rng::new(7))).collect();
    run_batched_ticks(lanes, 4, "sam-batched-metrics");
    assert!(
        metrics::TRAIN_TICKS.get() > ticks_before,
        "TRAIN_TICKS did not advance across batched training ticks"
    );
    for (i, h) in metrics::TRAIN_FWD_PHASE_US.iter().enumerate() {
        assert!(
            h.count() > phase_before[i],
            "forward phase histogram {i} recorded nothing"
        );
    }
}

#[test]
fn sam_steps_stay_lean_at_larger_scale() {
    // A second shape point (more heads, bigger memory) so the guarantee
    // isn't an artifact of one tiny configuration.
    let mut rng = Rng::new(9);
    let c = CoreConfig {
        x_dim: 6,
        y_dim: 6,
        hidden: 32,
        heads: 4,
        word: 16,
        mem_words: 256,
        k: 4,
        ann: AnnKind::Linear,
        seed: 78,
        ..CoreConfig::default()
    };
    let core = build_core(CoreKind::Sam, &c, &mut rng);
    run_core(core, 6, 6, "sam-large");
}
