//! End-to-end integration: every core trains on every task family for a
//! handful of updates without panicking, rolls its state back cleanly, and
//! the sparse cores' asymptotic signatures hold at test scale.

use sam::prelude::*;

fn tiny_cfg(task: &dyn Task, seed: u64) -> CoreConfig {
    CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: 16,
        heads: 2,
        word: 8,
        mem_words: 16,
        k: 2,
        k_l: 3,
        seed,
        ..CoreConfig::default()
    }
}

fn smoke_train(kind: CoreKind, task: &dyn Task, seed: u64) -> f64 {
    let cfg = tiny_cfg(task, seed);
    let mut rng = Rng::new(seed);
    let core = build_core(kind, &cfg, &mut rng);
    let mut trainer = Trainer::new(
        core,
        Box::new(RmsProp::new(1e-3)),
        TrainConfig { batch: 2, updates: 6, log_every: 3, seed, ..TrainConfig::default() },
    );
    let mut cur = Curriculum::fixed(task.base_level().min(4));
    let log = trainer.run(task, &mut cur);
    assert_eq!(log.total_episodes, 12);
    assert!(log.points.iter().all(|p| p.loss.is_finite()));
    log.best_loss()
}

#[test]
fn every_core_trains_on_copy() {
    let task = CopyTask::new(4);
    for kind in CoreKind::all() {
        let loss = smoke_train(kind, &task, 11);
        assert!(loss > 0.0, "{kind:?}");
    }
}

#[test]
fn every_core_trains_on_recall() {
    let task = AssociativeRecall::new(4);
    for kind in CoreKind::all() {
        smoke_train(kind, &task, 12);
    }
}

#[test]
fn memory_cores_train_on_sort_and_babi_and_omniglot() {
    let sort = PrioritySort::new(4);
    let babi = BabiTask::new();
    let omni = OmniglotTask::new(8, 6);
    for kind in [CoreKind::Sam, CoreKind::Sdnc, CoreKind::Dam] {
        smoke_train(kind, &sort, 13);
        smoke_train(kind, &babi, 14);
        smoke_train(kind, &omni, 15);
    }
}

#[test]
fn sam_with_every_ann_backend() {
    let task = CopyTask::new(4);
    for ann in [AnnKind::Linear, AnnKind::KdForest, AnnKind::Lsh, AnnKind::Hnsw] {
        let cfg = CoreConfig { ann, ..tiny_cfg(&task, 16) };
        let mut rng = Rng::new(16);
        let core = build_core(CoreKind::Sam, &cfg, &mut rng);
        let mut trainer = Trainer::new(
            core,
            Box::new(RmsProp::new(1e-3)),
            TrainConfig { batch: 2, updates: 4, log_every: 2, ..TrainConfig::default() },
        );
        let mut cur = Curriculum::fixed(3);
        trainer.run(&task, &mut cur);
    }
}

/// The paper's core claim at unit-test scale: SAM per-step cost must be
/// essentially flat in N while DAM/NTM grow linearly.
#[test]
fn sam_step_time_flat_in_n() {
    use std::time::Instant;
    let task = CopyTask::new(4);
    let mut times = Vec::new();
    for &n in &[256usize, 4096] {
        let cfg = CoreConfig { mem_words: n, ann: AnnKind::Linear, ..tiny_cfg(&task, 17) };
        let mut rng = Rng::new(17);
        let mut core = build_core(CoreKind::Sam, &cfg, &mut rng);
        core.reset();
        let x = vec![0.5; task.x_dim()];
        // warmup + measure forward+backward over a short episode
        for _ in 0..3 {
            core.forward(&x);
        }
        core.rollback();
        core.end_episode();
        core.reset();
        let t0 = Instant::now();
        let mut dys = Vec::new();
        for _ in 0..20 {
            let y = core.forward(&x);
            dys.push(vec![0.1; y.len()]);
        }
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        core.end_episode();
        times.push(t0.elapsed().as_secs_f64());
    }
    // SAM-linear's ANN query is O(N); even so the 16x memory growth must
    // cost well under 16x. (kd/LSH backends are sublinear; linear scan is
    // the worst case.)
    assert!(
        times[1] < times[0] * 10.0,
        "SAM step time scales too steeply: {times:?}"
    );
}

#[test]
fn checkpoint_preserves_eval_behaviour() {
    let task = CopyTask::new(4);
    let cfg = tiny_cfg(&task, 18);
    let mut rng = Rng::new(18);
    let core = build_core(CoreKind::Sam, &cfg, &mut rng);
    let mut trainer = Trainer::new(
        core,
        Box::new(RmsProp::new(1e-3)),
        TrainConfig { batch: 2, updates: 5, log_every: 5, ..TrainConfig::default() },
    );
    let mut cur = Curriculum::fixed(3);
    trainer.run(&task, &mut cur);
    let before = trainer.evaluate(&task, 3, 5, 99);

    let tmp = std::env::temp_dir().join("sam_e2e_ckpt.bin");
    sam::coordinator::save_checkpoint(trainer.core.as_mut(), &cfg, &tmp).unwrap();
    // Fresh core, load checkpoint, same eval.
    let mut rng2 = Rng::new(999);
    let mut core2 = build_core(CoreKind::Sam, &cfg, &mut rng2);
    sam::coordinator::load_checkpoint(core2.as_mut(), &cfg, &tmp).unwrap();
    let mut trainer2 = Trainer::new(
        core2,
        Box::new(RmsProp::new(1e-3)),
        TrainConfig::default(),
    );
    let after = trainer2.evaluate(&task, 3, 5, 99);
    let _ = std::fs::remove_file(tmp);
    // Memory init seeds differ between the two cores, so tiny numeric
    // differences are possible; task-level behaviour must match closely.
    assert!(
        (before - after).abs() <= 1.0,
        "checkpoint changed behaviour: {before} vs {after}"
    );
}
