//! The serving runtime's contracts (ISSUE 4 acceptance):
//!
//! * **Infer parity** — forward-only session outputs are bit-identical to
//!   train-mode `forward_into` for SAM/SDNC/DAM on a fixed seed.
//! * **Session isolation** — N interleaved sessions produce the same
//!   outputs as N sequential episodes, bit for bit.
//! * **Checkpoint round-trip** — save → load → identical outputs.
//! * **One weight copy** — a multi-session manager holds exactly one copy
//!   of the parameters regardless of session count, asserted through the
//!   manager's heap accounting (params + Σ sessions + tick scratch).
//! * **Zero tape** — `tape_bytes() == 0` while serving (the allocation
//!   side is in rust/tests/zero_alloc.rs).
//! * **Loopback serving** — the worker-pool TCP server keeps idle
//!   keep-alive connections (and their sessions) alive across gaps longer
//!   than the read timeout — the bug the old single-threaded server had.

use sam::coordinator::{read_checkpoint, save_checkpoint, server};
use sam::cores::{build_core, Core as _, CoreConfig, CoreKind};
use sam::nn::param::HasParams as _;
use sam::serving::{build_infer_model, InferModel as _, Session as _, SessionConfig, SessionManager};
use sam::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn small_cfg(seed: u64) -> CoreConfig {
    CoreConfig {
        x_dim: 4,
        y_dim: 3,
        hidden: 10,
        heads: 2,
        word: 6,
        mem_words: 16,
        k: 3,
        k_l: 4,
        seed,
        ..CoreConfig::default()
    }
}

fn random_inputs(x_dim: usize, t_len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..t_len)
        .map(|_| (0..x_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
        .collect()
}

#[test]
fn infer_mode_matches_train_mode_bitwise() {
    // The headline parity guarantee, per servable sparse/dense-control core.
    for kind in [CoreKind::Sam, CoreKind::Sdnc, CoreKind::Dam] {
        let cfg = small_cfg(31);
        let mut rng_t = Rng::new(31);
        let mut core = build_core(kind, &cfg, &mut rng_t);
        let mut rng_i = Rng::new(31);
        let model = build_infer_model(kind, &cfg, &mut rng_i, None);
        let mut session = model.open_session(None);
        let xs = random_inputs(cfg.x_dim, 8, 77);
        let mut yi = Vec::new();
        core.reset();
        for (t, x) in xs.iter().enumerate() {
            let yt = core.forward(x);
            model.step(session.as_mut(), x, &mut yi);
            for (a, b) in yt.iter().zip(&yi) {
                assert_eq!(a.to_bits(), b.to_bits(), "{kind:?} t={t}");
            }
            assert_eq!(session.tape_bytes(), 0, "{kind:?} grew a tape while serving");
        }
        core.rollback();
        core.end_episode();
    }
}

#[test]
fn interleaved_sessions_match_sequential_episodes() {
    // Isolation: stepping N sessions round-robin must equal running the
    // same N episodes one after another, bit for bit — no state can leak
    // between sessions.
    let cfg = small_cfg(32);
    let mut rng = Rng::new(32);
    let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
    let n = 4;
    let t_len = 8;
    let streams: Vec<Vec<Vec<f32>>> =
        (0..n).map(|i| random_inputs(cfg.x_dim, t_len, 100 + i as u64)).collect();

    // Sequential: one session at a time, full episode each.
    let mut sequential: Vec<Vec<Vec<u32>>> = Vec::new();
    for (i, stream) in streams.iter().enumerate() {
        let mut s = model.open_session(Some(500 + i as u64));
        let mut y = Vec::new();
        let mut bits = Vec::new();
        for x in stream {
            model.step(s.as_mut(), x, &mut y);
            bits.push(y.iter().map(|v| v.to_bits()).collect::<Vec<u32>>());
        }
        sequential.push(bits);
    }

    // Interleaved: all sessions advance in lockstep.
    let mut sessions: Vec<_> =
        (0..n).map(|i| model.open_session(Some(500 + i as u64))).collect();
    let mut y = Vec::new();
    for t in 0..t_len {
        for (i, s) in sessions.iter_mut().enumerate() {
            model.step(s.as_mut(), &streams[i][t], &mut y);
            let bits: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sequential[i][t], bits, "session {i} t={t} diverged when interleaved");
        }
    }
}

#[test]
fn checkpoint_roundtrip_identical_outputs() {
    // save → load → identical serving outputs, across a process-like
    // boundary (fresh model built from the same config/seed).
    let cfg = small_cfg(33);
    let mut rng = Rng::new(33);
    let mut core = build_core(CoreKind::Sam, &cfg, &mut rng);
    // Perturb the params so the checkpoint differs from the fresh init.
    let mut vals = core.save_values();
    for (i, v) in vals.iter_mut().enumerate() {
        *v += (i % 7) as f32 * 1e-3;
    }
    core.load_values(&vals);
    let tmp = std::env::temp_dir().join("sam_serving_ckpt_test.bin");
    save_checkpoint(core.as_mut(), &cfg, &tmp).unwrap();

    let params = read_checkpoint(&tmp).unwrap();
    assert_eq!(params, vals);
    let mut rng_a = Rng::new(33);
    let model_a = build_infer_model(CoreKind::Sam, &cfg, &mut rng_a, Some(&params));
    let mut rng_b = Rng::new(33);
    let model_b = build_infer_model(CoreKind::Sam, &cfg, &mut rng_b, Some(&params));
    let mut sa = model_a.open_session(None);
    let mut sb = model_b.open_session(None);
    let xs = random_inputs(cfg.x_dim, 6, 78);
    let (mut ya, mut yb) = (Vec::new(), Vec::new());
    for x in &xs {
        model_a.step(sa.as_mut(), x, &mut ya);
        model_b.step(sb.as_mut(), x, &mut yb);
        for (a, b) in ya.iter().zip(&yb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    let _ = std::fs::remove_file(tmp);
}

#[test]
fn shared_weights_hold_one_copy_regardless_of_session_count() {
    let cfg = small_cfg(34);
    let mut rng = Rng::new(34);
    let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
    let params_bytes = model.params_heap_bytes();
    assert!(params_bytes > 0);

    let mgr = SessionManager::new(model.clone(), SessionConfig::default());
    assert!(Arc::ptr_eq(mgr.model(), &model), "manager must share, not copy, the model");

    let mut per_session = Vec::new();
    for n in [1usize, 8, 32] {
        while mgr.session_count() < n {
            mgr.open_seeded(Some(mgr.session_count() as u64));
        }
        // One parameter copy no matter how many sessions exist…
        assert_eq!(mgr.params_heap_bytes(), params_bytes, "params scaled with sessions");
        // …and total heap is exactly params + Σ sessions + tick scratch.
        assert_eq!(
            mgr.heap_bytes(),
            mgr.params_heap_bytes() + mgr.state_heap_bytes() + mgr.batch_heap_bytes(),
            "heap accounting must be the sum of its parts"
        );
        per_session.push(mgr.state_heap_bytes() as f64 / n as f64);
    }
    // State grows ~linearly: per-session cost roughly constant.
    let (lo, hi) = (per_session[0], per_session[2]);
    assert!(
        (hi - lo).abs() / lo < 0.25,
        "per-session state not ~constant: {per_session:?}"
    );
}

#[test]
fn server_keeps_idle_connections_and_their_sessions() {
    // The idle-client fix, end to end over loopback: a keep-alive client
    // that pauses LONGER than the server's read timeout must keep both its
    // connection and its session state.
    use std::io::{BufRead, BufReader, Write};

    let cfg = small_cfg(35);
    let mut rng = Rng::new(35);
    let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
    let serve_cfg = server::ServeConfig {
        workers: 2,
        read_timeout: Duration::from_millis(10),
        tick: Duration::from_micros(100),
        ..server::ServeConfig::default()
    };
    let mgr = Arc::new(SessionManager::new(model, serve_cfg.session.clone()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = "127.0.0.1:47512";
    let handle = {
        let mgr = mgr.clone();
        let stop = stop.clone();
        let serve_cfg = serve_cfg.clone();
        std::thread::spawn(move || server::serve(mgr, addr, &serve_cfg, stop))
    };
    std::thread::sleep(Duration::from_millis(100));

    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    let mut roundtrip = |req: &str, line: &mut String| {
        writer.write_all(req.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        line.clear();
        reader.read_line(line).unwrap();
        sam::util::json::Json::parse(line.trim()).unwrap()
    };

    let r = roundtrip(r#"{"open": {"seed": 1}}"#, &mut line);
    let id = r.get("session").unwrap().as_f64().unwrap() as u64;
    let r1 = roundtrip(&format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#), &mut line);
    assert!(r1.get("output").is_some(), "{line}");

    // Idle well past the read timeout: the connection must be parked, not
    // dropped, and the session must survive.
    std::thread::sleep(Duration::from_millis(120));
    assert_eq!(mgr.session_count(), 1, "idle client lost its session");
    let r2 = roundtrip(&format!(r#"{{"session": {id}, "input": [0,1,1,0]}}"#), &mut line);
    assert!(r2.get("output").is_some(), "step after idle gap failed: {line}");

    // Reference: the same two steps on a direct session are identical —
    // the idle gap changed nothing.
    let r_out: Vec<f32> = r2
        .get("output")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let id2 = mgr.open_seeded(Some(1));
    let mut outs = Vec::new();
    mgr.step_many(&[(id2, vec![1.0, 0.0, 0.0, 1.0])], &mut outs);
    mgr.step_many(&[(id2, vec![0.0, 1.0, 1.0, 0.0])], &mut outs);
    let want = outs[0].as_ref().unwrap();
    for (a, b) in r_out.iter().zip(want) {
        assert!((a - b).abs() < 1e-5, "idle gap perturbed outputs");
    }

    stop.store(true, Ordering::Relaxed);
    drop(reader);
    drop(writer);
    handle.join().unwrap().unwrap();
}

#[test]
fn server_serves_concurrent_sessions_over_loopback() {
    // The CI integration path: open N sessions from N client threads, step
    // them concurrently (ticks coalesce server-side), assert every
    // response, close.
    use std::io::{BufRead, BufReader, Write};

    let cfg = small_cfg(36);
    let mut rng = Rng::new(36);
    let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
    let serve_cfg = server::ServeConfig {
        workers: 3,
        read_timeout: Duration::from_millis(10),
        tick: Duration::from_micros(200),
        ..server::ServeConfig::default()
    };
    let mgr = Arc::new(SessionManager::new(model, serve_cfg.session.clone()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = "127.0.0.1:47513";
    let handle = {
        let mgr = mgr.clone();
        let stop = stop.clone();
        let serve_cfg = serve_cfg.clone();
        std::thread::spawn(move || server::serve(mgr, addr, &serve_cfg, stop))
    };
    std::thread::sleep(Duration::from_millis(100));

    let clients: Vec<_> = (0..4)
        .map(|ci| {
            std::thread::spawn(move || {
                let stream = std::net::TcpStream::connect(addr).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let mut line = String::new();
                let mut send = |req: String, line: &mut String| {
                    writer.write_all(req.as_bytes()).unwrap();
                    writer.write_all(b"\n").unwrap();
                    writer.flush().unwrap();
                    line.clear();
                    reader.read_line(line).unwrap();
                    sam::util::json::Json::parse(line.trim()).unwrap()
                };
                let r = send(format!(r#"{{"open": {{"seed": {ci}}}}}"#), &mut line);
                let id = r.get("session").unwrap().as_f64().unwrap() as u64;
                for t in 0..8 {
                    let x = [t as f32 % 2.0, 1.0, 0.0, ci as f32 % 2.0];
                    let r = send(
                        format!(
                            r#"{{"session": {id}, "input": [{},{},{},{}]}}"#,
                            x[0], x[1], x[2], x[3]
                        ),
                        &mut line,
                    );
                    let out = r.get("output").expect("missing output").as_arr().unwrap();
                    assert_eq!(out.len(), 3);
                    assert!(out.iter().all(|v| v.as_f64().unwrap().is_finite()));
                }
                let r = send(format!(r#"{{"close": {id}}}"#), &mut line);
                assert_eq!(r.get("closed").unwrap().as_bool(), Some(true));
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    assert_eq!(mgr.session_count(), 0, "all sessions closed");
    stop.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

#[test]
fn server_protocol_errors_are_structured_and_nonfatal() {
    // Error paths over loopback (ISSUE 8 satellite): malformed JSON, an
    // unknown op, and a step after close must each return a structured
    // `{"error": …, "retryable": false}` reply — and leave the connection
    // fully usable and the session table consistent.
    use std::io::{BufRead, BufReader, Write};

    let cfg = small_cfg(37);
    let mut rng = Rng::new(37);
    let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
    let serve_cfg = server::ServeConfig {
        workers: 2,
        read_timeout: Duration::from_millis(10),
        tick: Duration::from_micros(100),
        ..server::ServeConfig::default()
    };
    let mgr = Arc::new(SessionManager::new(model, serve_cfg.session.clone()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = "127.0.0.1:47514";
    let handle = {
        let mgr = mgr.clone();
        let stop = stop.clone();
        let serve_cfg = serve_cfg.clone();
        std::thread::spawn(move || server::serve(mgr, addr, &serve_cfg, stop))
    };
    std::thread::sleep(Duration::from_millis(100));

    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    let mut roundtrip = |req: &str, line: &mut String| {
        writer.write_all(req.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        line.clear();
        reader.read_line(line).unwrap();
        sam::util::json::Json::parse(line.trim()).unwrap()
    };
    let assert_final_error = |r: &sam::util::json::Json, what: &str| {
        assert!(r.get("error").is_some(), "{what}: no error field");
        assert_eq!(
            r.get("retryable").and_then(|v| v.as_bool()),
            Some(false),
            "{what}: request-level failures must be final (retryable=false)"
        );
    };

    // Malformed JSON.
    let r = roundtrip("this is not json", &mut line);
    assert_final_error(&r, "malformed json");
    // Unknown op.
    let r = roundtrip(r#"{"frobnicate": true}"#, &mut line);
    assert_final_error(&r, "unknown op");
    // The connection survived both errors.
    let r = roundtrip(r#"{"ping": true}"#, &mut line);
    assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));

    // Step after close: structured error, ownership dropped, table clean.
    let r = roundtrip(r#"{"open": {"seed": 4}}"#, &mut line);
    let id = r.get("session").unwrap().as_f64().unwrap() as u64;
    let r = roundtrip(&format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#), &mut line);
    assert!(r.get("output").is_some());
    let r = roundtrip(&format!(r#"{{"close": {id}}}"#), &mut line);
    assert_eq!(r.get("closed").unwrap().as_bool(), Some(true));
    let r = roundtrip(&format!(r#"{{"session": {id}, "input": [1,0,0,1]}}"#), &mut line);
    assert_final_error(&r, "step after close");
    assert_eq!(mgr.session_count(), 0, "closed session must stay closed");
    // Still alive after the whole error gauntlet.
    let r = roundtrip(r#"{"ping": true}"#, &mut line);
    assert_eq!(r.get("pong").unwrap().as_bool(), Some(true));

    stop.store(true, Ordering::Relaxed);
    drop(reader);
    drop(writer);
    handle.join().unwrap().unwrap();
}

#[test]
fn server_closes_connection_on_oversized_line_and_frees_sessions() {
    // A line over the 1 MiB cap closes the connection (a newline-free
    // flood must not grow server memory without bound) — and the sessions
    // that connection owned are released, keeping the table consistent.
    use std::io::{BufRead, BufReader, Read, Write};

    let cfg = small_cfg(38);
    let mut rng = Rng::new(38);
    let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
    let serve_cfg = server::ServeConfig {
        workers: 2,
        read_timeout: Duration::from_millis(10),
        tick: Duration::from_micros(100),
        ..server::ServeConfig::default()
    };
    let mgr = Arc::new(SessionManager::new(model, serve_cfg.session.clone()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = "127.0.0.1:47515";
    let handle = {
        let mgr = mgr.clone();
        let stop = stop.clone();
        let serve_cfg = serve_cfg.clone();
        std::thread::spawn(move || server::serve(mgr, addr, &serve_cfg, stop))
    };
    std::thread::sleep(Duration::from_millis(100));

    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    writer.write_all(br#"{"open": {"seed": 6}}"#).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    reader.read_line(&mut line).unwrap();
    let r = sam::util::json::Json::parse(line.trim()).unwrap();
    assert!(r.get("session").is_some());
    assert_eq!(mgr.session_count(), 1);

    // One 2 MiB garbage line. The server must close the connection rather
    // than answer, and release the session the connection owned.
    let junk = vec![b'x'; 2 << 20];
    writer.write_all(&junk).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut rest = Vec::new();
    let n = reader.read_to_end(&mut rest).unwrap_or(0);
    assert_eq!(n, 0, "oversized line must be dropped, not answered: {rest:?}");

    // Session cleanup happens when a worker observes the closed state.
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    while mgr.session_count() != 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(mgr.session_count(), 0, "dropped connection must free its sessions");

    stop.store(true, Ordering::Relaxed);
    drop(reader);
    drop(writer);
    handle.join().unwrap().unwrap();
}
