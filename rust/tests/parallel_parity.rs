//! Determinism of the threaded data-parallel runtime: `Trainer` and
//! `ParallelTrainer` with 1, 2 and 4 workers on the same seed must produce
//! **bit-identical** loss curves, curriculum trajectories and final
//! parameters. Both follow the canonical batch protocol — whole batch
//! sampled up-front, per-episode gradients from zeroed accumulators,
//! fixed-order reduction in episode order on the main thread — so the
//! partitioning of episodes over threads can never change the arithmetic.
//!
//! Cores here use `AnnKind::Linear` (content-deterministic reads); the
//! approximate indexes keep per-count determinism but not cross-count
//! parity (their tree state is per-replica history-dependent) — see
//! `training::workers` docs and DESIGN.md.

use sam::prelude::*;
use sam::training::TrainLog;

fn core_cfg(task: &dyn Task, seed: u64) -> CoreConfig {
    CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: 12,
        heads: 2,
        word: 8,
        mem_words: 16,
        k: 2,
        k_l: 3,
        ann: AnnKind::Linear,
        seed,
        ..CoreConfig::default()
    }
}

fn train_cfg(seed: u64) -> TrainConfig {
    TrainConfig { lr: 2e-3, batch: 5, updates: 12, log_every: 2, seed, ..TrainConfig::default() }
}

fn curriculum() -> Curriculum {
    // Exponential so curriculum *decisions* (report ordering) are part of
    // the parity check, with a threshold loose enough to actually advance.
    let mut c = Curriculum::exponential(2, 16, 3.0);
    c.patience = 4;
    c
}

fn run_serial(kind: CoreKind, seed: u64) -> (TrainLog, Vec<f32>) {
    let task = CopyTask::new(4);
    let cfg = core_cfg(&task, seed);
    let mut rng = Rng::new(seed);
    let core = build_core(kind, &cfg, &mut rng);
    let mut t = Trainer::new(core, Box::new(RmsProp::new(2e-3)), train_cfg(seed));
    let mut cur = curriculum();
    let log = t.run(&task, &mut cur);
    let params = t.core.save_values();
    (log, params)
}

fn run_parallel(kind: CoreKind, seed: u64, workers: usize) -> (TrainLog, Vec<f32>) {
    let task = CopyTask::new(4);
    let cfg = core_cfg(&task, seed);
    let mut factory = |_i: usize| {
        let mut rng = Rng::new(seed);
        build_core(kind, &cfg, &mut rng)
    };
    let mut pt =
        ParallelTrainer::new(&mut factory, workers, Box::new(RmsProp::new(2e-3)), train_cfg(seed));
    let mut cur = curriculum();
    let log = pt.run(&task, &mut cur);
    let (mut core, _) = pt.into_primary();
    let params = core.save_values();
    (log, params)
}

fn assert_logs_bit_identical(a: &TrainLog, b: &TrainLog, what: &str) {
    assert_eq!(a.total_episodes, b.total_episodes, "{what}: episode counts");
    assert_eq!(a.final_level, b.final_level, "{what}: final curriculum level");
    assert_eq!(a.points.len(), b.points.len(), "{what}: log point counts");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.update, pb.update, "{what}: update index");
        assert_eq!(
            pa.loss.to_bits(),
            pb.loss.to_bits(),
            "{what}: loss differs at update {} ({} vs {})",
            pa.update,
            pa.loss,
            pb.loss
        );
        assert_eq!(
            pa.errors.to_bits(),
            pb.errors.to_bits(),
            "{what}: errors differ at update {}",
            pa.update
        );
        assert_eq!(pa.level, pb.level, "{what}: curriculum level at update {}", pa.update);
    }
}

fn assert_params_bit_identical(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: param counts");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: param[{i}] {x} vs {y}");
    }
}

#[test]
fn sam_serial_and_all_worker_counts_bit_identical() {
    let (serial_log, serial_params) = run_serial(CoreKind::Sam, 42);
    for workers in [1usize, 2, 4] {
        let (log, params) = run_parallel(CoreKind::Sam, 42, workers);
        assert_logs_bit_identical(&serial_log, &log, &format!("sam x{workers}"));
        assert_params_bit_identical(&serial_params, &params, &format!("sam x{workers}"));
    }
}

#[test]
fn lstm_serial_and_all_worker_counts_bit_identical() {
    let (serial_log, serial_params) = run_serial(CoreKind::Lstm, 7);
    for workers in [1usize, 2, 4] {
        let (log, params) = run_parallel(CoreKind::Lstm, 7, workers);
        assert_logs_bit_identical(&serial_log, &log, &format!("lstm x{workers}"));
        assert_params_bit_identical(&serial_params, &params, &format!("lstm x{workers}"));
    }
}

#[test]
fn training_actually_learns_under_parallelism() {
    // Guard against a determinism fix that silently zeroes the gradients:
    // the parallel run must still reduce the loss.
    let (log, _) = run_parallel(CoreKind::Lstm, 11, 2);
    assert!(log.points.len() >= 2);
    assert!(
        log.best_loss() <= log.points[0].loss,
        "no learning signal: {:?}",
        log.points.iter().map(|p| p.loss).collect::<Vec<_>>()
    );
}
