//! Property tests for the rollback substrate that O(1)-space BPTT rests on
//! (paper §3.4): journaled sparse writes must revert bit-exactly — verified
//! against the brute-force `snapshot`/`restore` path — and the CSR sparse
//! vector must round-trip dense↔sparse under random masks.

use sam::memory::store::{MemoryStore, WriteOp};
use sam::tensor::csr::SparseVec;
use sam::util::rng::Rng;

fn random_store(n: usize, w: usize, rng: &mut Rng) -> MemoryStore {
    let mut m = MemoryStore::zeros(n, w);
    for i in 0..n {
        for v in m.row_mut(i) {
            *v = rng.normal();
        }
    }
    m
}

fn random_write(n: usize, w: usize, rng: &mut Rng) -> WriteOp {
    let k = rng.int_in(1, 5);
    let idx = rng.sample_indices(n, k);
    let weights =
        SparseVec::from_pairs(idx.iter().map(|&i| (i, rng.normal())).collect());
    let erase_rows = match rng.below(3) {
        0 => vec![],
        1 => vec![rng.below(n)],
        // Erase can overlap the write support — the journal must still
        // record each touched row exactly once.
        _ => vec![rng.below(n), idx[0]],
    };
    let word: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
    WriteOp { erase_rows, weights, word }
}

/// Every intermediate state reached by a sequence of journaled writes must
/// be restored bit-exactly by reverting in reverse order — compared against
/// the ground-truth snapshots taken before each write.
#[test]
fn journal_revert_matches_snapshot_restore_at_every_step() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed);
        let (n, w) = (48, 6);
        let mut m = random_store(n, w, &mut rng);
        let t_steps = 40;
        let mut journals = Vec::with_capacity(t_steps);
        let mut snapshots = Vec::with_capacity(t_steps);
        for _ in 0..t_steps {
            snapshots.push(m.snapshot());
            journals.push(m.apply_write(&random_write(n, w, &mut rng)));
        }
        for (j, snap) in journals.iter().zip(&snapshots).rev() {
            m.revert(j);
            assert_eq!(&m.snapshot(), snap, "seed {seed}: intermediate state differs");
        }
    }
}

/// Reverting must agree with the O(N·W) restore path on the same op.
#[test]
fn single_write_revert_equals_restore() {
    for seed in 100..120u64 {
        let mut rng = Rng::new(seed);
        let (n, w) = (32, 8);
        let mut via_journal = random_store(n, w, &mut rng);
        let mut via_restore = via_journal.clone();
        let op = random_write(n, w, &mut rng);

        let before = via_restore.snapshot();
        let j = via_journal.apply_write(&op);
        via_restore.apply_write(&op);

        via_journal.revert(&j);
        via_restore.restore(&before);
        assert_eq!(
            via_journal.snapshot(),
            via_restore.snapshot(),
            "seed {seed}: journal revert != snapshot restore"
        );
        assert_eq!(via_journal.snapshot(), before, "seed {seed}: state not restored");
    }
}

/// Journals are O(K·W): their size must not depend on N.
#[test]
fn journal_cost_independent_of_memory_size() {
    let op = WriteOp {
        erase_rows: vec![1],
        weights: SparseVec::from_pairs(vec![(1, 0.5), (3, -0.25), (7, 1.0)]),
        word: vec![0.5; 16],
    };
    let mut sizes = Vec::new();
    for &n in &[64usize, 1024, 16384] {
        let mut rng = Rng::new(9);
        let mut m = random_store(n, 16, &mut rng);
        sizes.push(m.apply_write(&op).heap_bytes());
    }
    assert_eq!(sizes[0], sizes[1]);
    assert_eq!(sizes[1], sizes[2]);
}

/// Dense → sparse → dense round-trips exactly under random masks, and
/// sparse → dense → sparse preserves the support and values.
#[test]
fn sparse_vec_roundtrips_under_random_masks() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed);
        let n = rng.int_in(1, 128);

        // Random mask with a spread of densities, including all-zero.
        let density = rng.uniform();
        let dense: Vec<f32> = (0..n)
            .map(|_| {
                if rng.bernoulli(density) {
                    let v = rng.normal();
                    if v == 0.0 {
                        1.0
                    } else {
                        v
                    }
                } else {
                    0.0
                }
            })
            .collect();

        // dense → sparse → dense is exact (threshold 0 keeps every nonzero).
        let sv = SparseVec::from_dense_thresholded(&dense, 0.0);
        assert_eq!(sv.to_dense(n), dense, "seed {seed}: dense roundtrip");
        assert_eq!(sv.nnz(), dense.iter().filter(|&&v| v != 0.0).count());

        // Index/value invariants: strictly ascending support, get() agrees.
        assert!(sv.idx.windows(2).all(|w| w[0] < w[1]), "seed {seed}: unsorted idx");
        for (i, &d) in dense.iter().enumerate() {
            assert_eq!(sv.get(i), d, "seed {seed}: get({i})");
        }

        // sparse → dense → sparse is exact for nonzero distinct pairs.
        let back = SparseVec::from_dense_thresholded(&sv.to_dense(n), 0.0);
        assert_eq!(back, sv, "seed {seed}: sparse roundtrip");
    }
}

/// from_pairs must behave like dense accumulation (duplicate indices add).
#[test]
fn from_pairs_matches_dense_accumulation() {
    for seed in 200..230u64 {
        let mut rng = Rng::new(seed);
        let n = 32;
        let pairs: Vec<(usize, f32)> = (0..rng.int_in(0, 20))
            .map(|_| (rng.below(n), rng.normal()))
            .collect();
        let mut dense = vec![0.0f32; n];
        for &(i, v) in &pairs {
            dense[i] += v;
        }
        let sv = SparseVec::from_pairs(pairs);
        for (i, &d) in dense.iter().enumerate() {
            assert!(
                (sv.get(i) - d).abs() < 1e-5,
                "seed {seed}: accumulated value differs at {i}"
            );
        }
    }
}
