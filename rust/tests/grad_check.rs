//! Central-difference gradient checks for every core (`CoreKind::all()`)
//! on a tiny config — the scaffolding every later optimisation PR is
//! judged against: if a refactor breaks a backward pass, this fails.
//!
//! Tolerances: f32 forward passes limit what central differences can
//! resolve — cancellation noise alone is ~|L|·ε_f32/eps ≈ 1e-3 absolute
//! here, so a hard 1e-3 relative bound per coordinate would flake on
//! coordinates with small gradients. The checker instead bounds the
//! *fraction* of sampled coordinates outside a relative tolerance;
//! systematic backward bugs fail ~100% of coordinates (verified by
//! mutation when the checker was introduced), so a ≤1/8 bound is a strong
//! signal. Discrete structure (ANN top-K, LRA argmin) flipping under the
//! FD perturbation accounts for the tolerated few.

use sam::cores::grad_check::{check_core_gradients, random_episode};
use sam::prelude::*;

fn tiny_cfg(seed: u64) -> CoreConfig {
    CoreConfig {
        x_dim: 4,
        y_dim: 3,
        hidden: 10,
        heads: 2,
        word: 6,
        mem_words: 16,
        k: 3,
        k_l: 4,
        ann: AnnKind::Linear,
        seed,
        ..CoreConfig::default()
    }
}

/// Per-kind (eps, rel tolerance, allowed failure numerator out of 8).
fn thresholds(kind: CoreKind) -> (f32, f32, usize) {
    match kind {
        // No discrete structure: every sampled coordinate must pass.
        CoreKind::Lstm => (1e-2, 0.15, 0),
        CoreKind::Ntm | CoreKind::Dam => (1e-2, 0.2, 1),
        CoreKind::Sam => (5e-3, 0.2, 1),
        CoreKind::Dnc | CoreKind::Sdnc => (1e-2, 0.25, 1),
    }
}

#[test]
fn every_core_passes_central_difference_gradient_checks() {
    for kind in CoreKind::all() {
        let seed = 1000 + kind as u64;
        let cfg = tiny_cfg(seed);
        let mut rng = Rng::new(seed);
        let mut core = build_core(kind, &cfg, &mut rng);
        let (xs, ts) = random_episode(cfg.x_dim, cfg.y_dim, 5, &mut rng);
        let (eps, tol, allowed_eighths) = thresholds(kind);
        let (checked, failed) =
            check_core_gradients(core.as_mut(), &xs, &ts, &mut rng, 6, eps, tol);
        assert!(checked >= 30, "{kind:?}: only {checked} coordinates sampled");
        assert!(
            failed * 8 <= checked * allowed_eighths,
            "{kind:?}: {failed}/{checked} gradient checks failed \
             (allowed {allowed_eighths}/8 of sampled coordinates)"
        );
    }
}

#[test]
fn gradient_checks_catch_a_broken_backward() {
    // Negative control: corrupt the loss gradient scale and verify the
    // checker actually fails — guards against a vacuously-green checker.
    let cfg = tiny_cfg(7);
    let mut rng = Rng::new(7);
    let mut core = build_core(CoreKind::Lstm, &cfg, &mut rng);
    let (xs, ts) = random_episode(cfg.x_dim, cfg.y_dim, 5, &mut rng);
    // Run the analytic pass against *doubled* targets but FD against the
    // originals: the analytic grads no longer match the FD loss surface.
    let ts_wrong: Vec<Vec<f32>> = ts.iter().map(|t| t.iter().map(|v| v * 2.0).collect()).collect();
    core.zero_grads();
    core.reset();
    let mut dys = Vec::new();
    for (x, t) in xs.iter().zip(&ts_wrong) {
        let y = core.forward(x);
        dys.push(sam::nn::loss::sigmoid_xent(&y, t).1);
    }
    for dy in dys.iter().rev() {
        core.backward(dy);
    }
    core.end_episode();
    let corrupted = core.save_grads();

    // Honest pass for comparison.
    let mut rng2 = Rng::new(7);
    let mut core2 = build_core(CoreKind::Lstm, &cfg, &mut rng2);
    core2.zero_grads();
    core2.reset();
    let mut dys2 = Vec::new();
    for (x, t) in xs.iter().zip(&ts) {
        let y = core2.forward(x);
        dys2.push(sam::nn::loss::sigmoid_xent(&y, t).1);
    }
    for dy in dys2.iter().rev() {
        core2.backward(dy);
    }
    core2.end_episode();
    let honest = core2.save_grads();

    let diff: f32 = corrupted
        .iter()
        .zip(&honest)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1e-3, "corrupted targets must change the gradients");
}
