//! Bitwise-parity guard for the `SparseMemoryEngine` port: a fixed-seed
//! SAM episode's per-step losses and post-episode parameters/gradients,
//! captured as a golden fixture.
//!
//! The engine refactor was made value-preserving by construction (same RNG
//! draw order, same float-operation order, same ring/journal sequencing);
//! this test pins that property going forward. The fixture is **blessed on
//! first run** — if `rust/tests/fixtures/sam_episode_trace.txt` is absent
//! it is written and the test passes — and compared bit-exactly on every
//! later run, so any future change to SAM numerics (intentional or not)
//! trips this test until the fixture is deliberately re-blessed by
//! deleting the file and re-running.

use sam::nn::loss::sigmoid_xent;
use sam::prelude::*;
use sam::tensor::simd::kernel_path_name;
use std::fmt::Write as _;
use std::path::PathBuf;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/sam_episode_trace.txt")
}

/// First line of the fixture: which kernel dispatch produced it. SIMD
/// reorders float additions (DESIGN.md's re-bless case), so a fixture is
/// only bit-comparable on the dispatch path that blessed it; the header is
/// what lets a scalar machine skip a fixture blessed on AVX2 (and vice
/// versa) instead of failing on summation-order noise.
fn kernel_header() -> String {
    format!("kernel {}\n", kernel_path_name())
}

/// Split a fixture into (recorded kernel path, trace body). Header-less
/// fixtures predate SIMD dispatch and were produced by the scalar kernels.
fn parse_fixture(golden: &str) -> (&str, &str) {
    match golden.strip_prefix("kernel ") {
        Some(rest) => rest.split_once('\n').unwrap_or((rest, "")),
        None => ("scalar", golden),
    }
}

/// Deterministic SAM episode trace. Losses are recorded as exact f32 bit
/// patterns and the parameter/gradient checksums as exact f64 bit patterns
/// (accumulated in the fixed `visit_params` order), so a comparison failure
/// means a genuine numeric divergence, not formatting noise.
fn episode_trace() -> String {
    let cfg = CoreConfig {
        x_dim: 4,
        y_dim: 3,
        hidden: 12,
        heads: 2,
        word: 6,
        mem_words: 24,
        k: 3,
        ann: AnnKind::Linear,
        seed: 20260801,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(777);
    let mut core = build_core(CoreKind::Sam, &cfg, &mut rng);
    let t_len = 12;
    let xs: Vec<Vec<f32>> = (0..t_len)
        .map(|_| (0..cfg.x_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
        .collect();
    let ts: Vec<Vec<f32>> = (0..t_len)
        .map(|_| (0..cfg.y_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect())
        .collect();

    core.zero_grads();
    core.reset();
    let mut out = String::new();
    let mut dys = Vec::new();
    for (x, t) in xs.iter().zip(&ts) {
        let y = core.forward(x);
        let (loss, dy) = sigmoid_xent(&y, t);
        writeln!(out, "loss {:08x}", loss.to_bits()).unwrap();
        dys.push(dy);
    }
    for dy in dys.iter().rev() {
        core.backward(dy);
    }
    core.end_episode();

    let (mut wsum, mut gsum) = (0.0f64, 0.0f64);
    core.visit_params(&mut |p| {
        for i in 0..p.len() {
            wsum += p.w.data[i] as f64;
            gsum += p.g.data[i] as f64;
        }
    });
    writeln!(out, "wsum {:016x}", wsum.to_bits()).unwrap();
    writeln!(out, "gsum {:016x}", gsum.to_bits()).unwrap();
    out
}

#[test]
fn sam_episode_matches_golden_fixture() {
    let trace = episode_trace();
    let path = fixture_path();
    match std::fs::read_to_string(&path) {
        Ok(golden) => {
            let (recorded, body) = parse_fixture(&golden);
            if recorded != kernel_path_name() {
                // A fixture is only bit-comparable on the kernel path that
                // blessed it (SIMD changes float summation order). This is
                // a skip, not a failure, even under SAM_REQUIRE_FIXTURE:
                // the fixture leg in CI runs on the blessing dispatch.
                eprintln!(
                    "skipping strict fixture compare: fixture at {} was blessed on \
                     '{recorded}' kernels, this run dispatches '{}' (delete the fixture \
                     on the blessing leg to re-bless)",
                    path.display(),
                    kernel_path_name()
                );
                return;
            }
            assert_eq!(
                trace, body,
                "SAM episode numerics diverged from the golden fixture at {}; \
                 if the change is intentional, delete the fixture and re-run to re-bless",
                path.display()
            );
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            // A missing fixture only blesses when explicitly allowed to
            // (the default, for first-time local runs — commit the written
            // file so later runs and CI checkouts actually compare).
            // Set SAM_REQUIRE_FIXTURE=1 (e.g. in CI) to make absence fail.
            if std::env::var_os("SAM_REQUIRE_FIXTURE").is_some() {
                panic!("golden fixture missing at {} (SAM_REQUIRE_FIXTURE set)", path.display());
            }
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            let blessed = format!("{}{}", kernel_header(), trace);
            std::fs::write(&path, &blessed).unwrap();
            // Read-back check: the blessed fixture must round-trip.
            assert_eq!(std::fs::read_to_string(&path).unwrap(), blessed);
            eprintln!(
                "blessed golden fixture at {} ({} kernels) — commit it so this guard has teeth",
                path.display(),
                kernel_path_name()
            );
        }
        Err(e) => panic!("could not read golden fixture at {}: {e}", path.display()),
    }
}

#[test]
fn fixture_kernel_header_roundtrip() {
    let blessed = format!("{}loss 3f000000\n", kernel_header());
    let (rec, body) = parse_fixture(&blessed);
    assert_eq!(rec, kernel_path_name());
    assert_eq!(body, "loss 3f000000\n");
    // Header-less fixtures (pre-SIMD) read as scalar-blessed.
    let (rec, body) = parse_fixture("loss 3f000000\n");
    assert_eq!(rec, "scalar");
    assert_eq!(body, "loss 3f000000\n");
}

#[test]
fn sam_episode_trace_is_deterministic() {
    // The fixture is only meaningful if the trace itself is reproducible
    // within one build: two fresh runs must agree bit-for-bit.
    assert_eq!(episode_trace(), episode_trace());
}

#[test]
fn engine_accounting_matches_independent_expectations() {
    // Accounting guard with *independently computed* ground truths (the
    // bench `fig1_memory` runs the same check before measuring Fig 1b):
    // summing the engine's own accessors back together would be
    // tautological, so the sizes asserted here are derived from N/W/K
    // directly.
    let (n, word, heads, k, t_steps) = (32usize, 8usize, 2usize, 4usize, 6usize);
    let cfg = CoreConfig {
        x_dim: 4,
        y_dim: 3,
        hidden: 10,
        heads,
        word,
        mem_words: n,
        k,
        ann: AnnKind::Linear,
        seed: 5,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(5);
    let mut core = sam::cores::sam::SamCore::new(&cfg, &mut rng);
    core.reset();
    for _ in 0..t_steps {
        core.forward(&[1.0, 0.0, 0.0, 1.0]);
    }
    let e = core.engine();
    assert_eq!(e.store_heap_bytes(), n * word * 4, "store accounting drifted");
    assert_eq!(
        e.ring_heap_bytes(),
        2 * n * std::mem::size_of::<usize>(),
        "ring accounting drifted"
    );
    assert!(e.ann_heap_bytes() >= n * word * 4, "ANN must account its row copies");
    // One journal per head-step: ≥K distinct rows once reads are warm,
    // ≥1 (the LRA erase) on the first step where w̃^R is still empty.
    let min_journal = heads * ((t_steps - 1) * k + 1) * word * 4;
    assert!(
        e.journal_heap_bytes() >= min_journal,
        "live tape accounts {} B, expected >= {min_journal} B",
        e.journal_heap_bytes()
    );
    assert_eq!(
        e.heap_bytes(),
        e.store_heap_bytes()
            + e.ann_heap_bytes()
            + e.ring_heap_bytes()
            + e.journal_heap_bytes()
            + e.grad_heap_bytes()
    );
    core.rollback();
    core.end_episode();
    assert_eq!(core.engine().tape_bytes(), 0, "rollback must drain the journal tape");
}
