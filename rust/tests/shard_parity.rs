//! Sharded-memory parity and determinism blitz (see `memory::sharded`).
//!
//! * **Bit-parity**: for `AnnKind::Linear` the sharded engine's merge rule
//!   reproduces the unsharded scan order exactly, so the ENTIRE training
//!   stack — per-step losses, post-episode parameters AND gradients — must
//!   be bit-identical between S=1 and any S, for SAM and SDNC alike.
//! * **Per-run determinism**: kd-tree / LSH / HNSW shards see different row
//!   subsets than one big index, so S-parity is not promised — but two
//!   identical runs must agree bit-for-bit.
//! * **Rollback fuzz**: random interleavings of write / read / rollback /
//!   reset on a sharded engine must restore memory bit-exactly, keep every
//!   shard's ANN in sync, march in lockstep with an unsharded reference,
//!   and never fall off the incremental ANN-maintenance path
//!   (`full_rebuilds` pinned).
//!
//! Across the matrix below (2 cores × seeds × S ∈ {1,2,3,8} × episodes,
//! plus the kd/LSH and fuzz sections) this exercises ~200 randomized
//! episodes per run. CI re-runs the suite with `SAM_TEST_SHARDS=4`, which
//! adds S=4 to every shard set here (`sam::util::env_shards`).

use sam::memory::sharded::ShardedMemoryEngine;
use sam::nn::loss::sigmoid_xent;
use sam::prelude::*;
use sam::tensor::csr::SparseVec;
use sam::tensor::workspace::Workspace;
use sam::util::env_shards;

/// Shard counts under test: the built-ins plus CI's env override.
fn shard_set(base: &[usize]) -> Vec<usize> {
    let mut s: Vec<usize> = base.to_vec();
    if let Some(extra) = env_shards() {
        if !s.contains(&extra) {
            s.push(extra);
        }
    }
    s
}

fn small_cfg(kind: CoreKind, shards: usize, seed: u64, ann: AnnKind) -> CoreConfig {
    CoreConfig {
        x_dim: 4,
        y_dim: 3,
        hidden: 10,
        heads: 2,
        word: 6,
        mem_words: 24,
        k: 3,
        k_l: 4,
        ann,
        shards,
        seed: seed ^ ((kind as u64) << 8),
        ..CoreConfig::default()
    }
}

/// Bit-level fingerprint of `episodes` fwd+bwd episodes: every per-step
/// loss as f32 bits, then the f64 bit patterns of Σw and Σg accumulated in
/// `visit_params` order (the engine_parity.rs convention).
fn fingerprint(
    kind: CoreKind,
    ann: AnnKind,
    shards: usize,
    seed: u64,
    episodes: usize,
) -> Vec<u64> {
    let cfg = small_cfg(kind, shards, seed, ann);
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37) ^ 0xC0FE);
    let mut core = build_core(kind, &cfg, &mut rng);
    let t_len = 6;
    let mut out = Vec::new();
    let mut y = Vec::new();
    for _ep in 0..episodes {
        core.zero_grads();
        core.reset();
        let mut dys = Vec::new();
        for _t in 0..t_len {
            let x: Vec<f32> =
                (0..cfg.x_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
            let t: Vec<f32> =
                (0..cfg.y_dim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
            core.forward_into(&x, &mut y);
            let (loss, dy) = sigmoid_xent(&y, &t);
            out.push(loss.to_bits() as u64);
            dys.push(dy);
        }
        for dy in dys.iter().rev() {
            core.backward(dy);
        }
        core.end_episode();
        let (mut wsum, mut gsum) = (0.0f64, 0.0f64);
        core.visit_params(&mut |p| {
            for i in 0..p.len() {
                wsum += p.w.data[i] as f64;
                gsum += p.g.data[i] as f64;
            }
        });
        out.push(wsum.to_bits());
        out.push(gsum.to_bits());
    }
    out
}

#[test]
fn linear_sharding_is_bit_identical_to_unsharded_for_sam_and_sdnc() {
    // The acceptance criterion: S ∈ {2,3,8} (and CI's extra S) match S=1
    // bit-for-bit — losses, params and grads — on both engine-backed
    // sparse cores, across several seeds and episodes (buffer pools warm
    // mid-fingerprint, so recycling divergence would also trip this).
    for kind in [CoreKind::Sam, CoreKind::Sdnc] {
        for seed in 0..5u64 {
            let base = fingerprint(kind, AnnKind::Linear, 1, seed, 3);
            for s in shard_set(&[2, 3, 8]) {
                if s == 1 {
                    continue;
                }
                let sharded = fingerprint(kind, AnnKind::Linear, s, seed, 3);
                assert_eq!(
                    base, sharded,
                    "{kind:?} S={s} seed={seed} diverged bitwise from S=1"
                );
            }
        }
    }
}

#[test]
fn kd_and_lsh_sharded_training_is_run_deterministic() {
    // No S-parity promise for the approximate backends — but identical
    // runs must produce identical bits at every S.
    for ann in [AnnKind::KdForest, AnnKind::Lsh, AnnKind::Hnsw] {
        for s in shard_set(&[2, 3]) {
            let a = fingerprint(CoreKind::Sam, ann, s, 11, 2);
            let b = fingerprint(CoreKind::Sam, ann, s, 11, 2);
            assert_eq!(a, b, "{ann:?} S={s} must be deterministic per run");
            // Losses must at least be finite (f32 bit patterns of NaN/inf
            // would indicate a broken merge for approximate backends).
            for &bits in &a {
                if bits <= u32::MAX as u64 {
                    assert!(f32::from_bits(bits as u32).is_finite());
                }
            }
        }
    }
}

/// One random engine-level op applied identically to the sharded engine
/// and (for Linear) its unsharded reference.
fn random_word(rng: &mut Rng, w: usize) -> Vec<f32> {
    (0..w).map(|_| rng.normal()).collect()
}

#[test]
fn rollback_fuzz_keeps_every_shard_in_sync_with_no_full_rebuilds() {
    // Random interleavings of write / read / rollback / reset. After every
    // rollback or reset the sharded memory must be bit-identical to the
    // episode start, the unsharded reference must agree at every step
    // (Linear), the shard ANNs must answer in sync, and the whole run must
    // stay on the incremental ANN path: full_rebuilds pinned at its
    // post-construction value.
    let (n, word, k) = (64usize, 6usize, 3usize);
    for s in shard_set(&[2, 3, 8]) {
        if s == 1 {
            continue;
        }
        for seed in 0..4u64 {
            let mut r1 = Rng::new(1000 + seed);
            let mut r2 = Rng::new(1000 + seed);
            let mut e =
                ShardedMemoryEngine::new_sparse(n, word, k, 0.005, AnnKind::Linear, &mut r1, s);
            let mut reference =
                ShardedMemoryEngine::new_sparse(n, word, k, 0.005, AnnKind::Linear, &mut r2, 1);
            let rebuilds0 = e.ann_full_rebuilds();
            let start = e.snapshot();
            assert_eq!(start, reference.snapshot());
            let mut ws = Workspace::new();
            let mut ws_ref = Workspace::new();
            let mut rng = Rng::new(7000 + seed);
            let mut wp = SparseVec::new();
            let mut wp_ref = SparseVec::new();
            for _op in 0..60 {
                match rng.below(10) {
                    // 0..=5: write (most common — builds tape depth)
                    0..=5 => {
                        let wd = random_word(&mut rng, word);
                        let (ar, gr) = (rng.normal(), rng.normal());
                        let ga = e.sparse_write(ar, gr, &wp, &wd, &mut ws);
                        let gb = reference.sparse_write(ar, gr, &wp_ref, &wd, &mut ws_ref);
                        assert_eq!(ga.lra_row, gb.lra_row, "LRA drift (S={s} seed={seed})");
                        assert_eq!(ga.weights, gb.weights);
                    }
                    // 6..=7: read (touches the ring, exercises the merge)
                    6..=7 => {
                        let q = random_word(&mut rng, word);
                        let ra = e.read_topk(vec![(q.clone(), 0.4)]);
                        let rb = reference.read_topk(vec![(q, 0.4)]);
                        assert_eq!(ra[0].read.rows, rb[0].read.rows);
                        assert_eq!(ra[0].r, rb[0].r);
                        wp = ra.into_iter().next().unwrap().weights;
                        wp_ref = rb.into_iter().next().unwrap().weights;
                    }
                    // 8: rollback
                    8 => {
                        e.rollback_ws(&mut ws);
                        reference.rollback_ws(&mut ws_ref);
                        assert_eq!(e.snapshot(), start, "rollback not bit-exact (S={s})");
                        assert_eq!(e.tape_bytes(), 0);
                    }
                    // 9: reset (abandoned episode; also resets ring + wp)
                    _ => {
                        e.reset(&mut ws);
                        reference.reset(&mut ws_ref);
                        assert_eq!(e.snapshot(), start, "reset not bit-exact (S={s})");
                        wp = SparseVec::new();
                        wp_ref = SparseVec::new();
                    }
                }
                assert_eq!(e.snapshot(), reference.snapshot(), "step drift (S={s})");
            }
            e.reset(&mut ws);
            reference.reset(&mut ws_ref);
            assert_eq!(e.snapshot(), start);
            // Every shard ANN answers in sync after the churn: a self-query
            // on each row's own contents must return that row top-1.
            for i in (0..n).step_by(7) {
                let r = e.read_topk(vec![(e.row(i).to_vec(), 8.0)]);
                assert_eq!(r[0].read.rows[0], i, "shard ANN out of sync at row {i} (S={s})");
            }
            assert_eq!(
                e.ann_full_rebuilds(),
                rebuilds0,
                "fuzz left the incremental path (S={s} seed={seed})"
            );
        }
    }
}

/// Shared approximate-backend fuzz body: writes interleaved with
/// rollback/reset; memory must restore bit-exactly and every shard's ANN
/// must keep answering self-queries (contents in sync). Returns the final
/// `ann_full_rebuilds()` so callers can pin the maintenance cadence.
fn approx_fuzz(kind: AnnKind, n: usize, word: usize, s: usize, seed: u64) -> usize {
    let mut r = Rng::new(seed);
    let mut e = ShardedMemoryEngine::new_sparse(n, word, 4, 0.005, kind, &mut r, s);
    let start = e.snapshot();
    let mut ws = Workspace::new();
    let mut rng = Rng::new(seed ^ 0xFFFF);
    let mut wp = SparseVec::new();
    for round in 0..4 {
        for _ in 0..6 {
            let wd = random_word(&mut rng, word);
            let gate = e.sparse_write(rng.normal(), rng.normal(), &wp, &wd, &mut ws);
            drop(gate);
            // Keep the recurrent support K-bounded via a real read (the
            // training regime) instead of chaining gate supports.
            let q = random_word(&mut rng, word);
            let rd = e.read_topk(vec![(q, 0.4)]);
            wp = rd.into_iter().next().unwrap().weights;
        }
        if round % 2 == 0 {
            e.rollback_ws(&mut ws);
        } else {
            e.reset(&mut ws);
            wp = SparseVec::new();
        }
        assert_eq!(e.snapshot(), start, "{kind:?} shard rollback not bit-exact (S={s})");
        for i in (0..n).step_by(41) {
            let r = e.read_topk(vec![(e.row(i).to_vec(), 8.0)]);
            assert_eq!(r[0].read.rows[0], i, "{kind:?} shard ANN lost row {i} (S={s})");
        }
    }
    e.ann_full_rebuilds()
}

#[test]
fn rollback_fuzz_kdforest_shards_resync_with_deterministic_cadence() {
    // kd-trees rebuild every ~n_local updates BY DESIGN (the paper's
    // insert-count trigger), so the pin here is that the rebuild cadence
    // is a deterministic function of the op sequence — identical runs land
    // on the identical count — while rollback/reset keep contents in sync.
    for s in shard_set(&[2, 4]) {
        if s == 1 {
            continue;
        }
        let a = approx_fuzz(AnnKind::KdForest, 256, 8, s, 31);
        let b = approx_fuzz(AnnKind::KdForest, 256, 8, s, 31);
        assert_eq!(a, b, "kd rebuild cadence must be deterministic (S={s})");
    }
}

#[test]
fn rollback_fuzz_lsh_shards_stay_on_the_incremental_path() {
    // LSH compacts every 8·n_local ops; the fuzz stays far below that, so
    // full_rebuilds is pinned at its post-construction value: rollback and
    // reset must never force a full rehash.
    for s in shard_set(&[2, 4]) {
        if s == 1 {
            continue;
        }
        let mut r = Rng::new(41);
        let probe =
            ShardedMemoryEngine::new_sparse(256, 8, 4, 0.005, AnnKind::Lsh, &mut r, s);
        let rebuilds0 = probe.ann_full_rebuilds();
        drop(probe);
        let after = approx_fuzz(AnnKind::Lsh, 256, 8, s, 41);
        assert_eq!(
            after, rebuilds0,
            "rollback/reset forced an LSH rehash off the incremental path (S={s})"
        );
    }
}

#[test]
fn rollback_fuzz_hnsw_shards_never_rebuild() {
    // HNSW has no automatic rebuild trigger at all: update_row relinks in
    // place and remove_row repairs neighbors, so the counter is pinned at
    // exactly 0 — construction included — across write/rollback/reset churn.
    for s in shard_set(&[2, 4]) {
        if s == 1 {
            continue;
        }
        let after = approx_fuzz(AnnKind::Hnsw, 256, 8, s, 51);
        assert_eq!(
            after, 0,
            "rollback/reset knocked an HNSW shard off the incremental path (S={s})"
        );
    }
}

#[test]
fn sharded_serving_sessions_match_unsharded_bitwise() {
    // `--shards` flows through the serving stack: a SessionManager over an
    // S=4 SAM model must serve the exact bits of the S=1 model (Linear),
    // session-managed end to end.
    let mk = |shards: usize| {
        let cfg = small_cfg(CoreKind::Sam, shards, 5, AnnKind::Linear);
        let mut rng = Rng::new(55);
        build_infer_model(CoreKind::Sam, &cfg, &mut rng, None)
    };
    let m1 = SessionManager::new(mk(1), SessionConfig::default());
    let m4 = SessionManager::new(mk(4), SessionConfig::default());
    let id1 = m1.open_seeded(None);
    let id4 = m4.open_seeded(None);
    let mut rng = Rng::new(77);
    let (mut y1, mut y4) = (Vec::new(), Vec::new());
    for ep in 0..2 {
        for _t in 0..6 {
            let x: Vec<f32> =
                (0..4).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
            m1.step(id1, &x, &mut y1).unwrap();
            m4.step(id4, &x, &mut y4).unwrap();
            let b1: Vec<u32> = y1.iter().map(|v| v.to_bits()).collect();
            let b4: Vec<u32> = y4.iter().map(|v| v.to_bits()).collect();
            assert_eq!(b1, b4, "serving outputs diverged (ep {ep})");
        }
        m1.reset(id1).unwrap();
        m4.reset(id4).unwrap();
    }
    assert!(m1.close(id1));
    assert!(m4.close(id4));
}
