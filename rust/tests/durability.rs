//! Durability suite: kill-and-restart recovery through session spill
//! files, corrupt-spill detection, and (under the `fault-inject` feature)
//! deterministic crash/IO-failure scenarios.
//!
//! The acceptance bar (ISSUE 8): a spilled session resumes with
//! bit-identical next-step outputs for ann=linear at f32 AND bf16 rows;
//! a corrupted or truncated spill is detected via CRC, dropped, and
//! counted — never loaded.
//!
//! Every test in this binary serializes on one lock: the fault-injection
//! registry is process-global, so a fault armed by one test must never be
//! observed by another test's spill I/O running concurrently.

use sam::ann::AnnKind;
use sam::cores::{CoreConfig, CoreKind};
use sam::serving::{
    build_infer_model, spill, InferModel, SessionConfig, SessionManager,
};
use sam::tensor::rowcodec::RowFormat;
use sam::util::rng::Rng;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    // A panicking test must not wedge the rest of the suite.
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn core_cfg(row_format: RowFormat) -> CoreConfig {
    CoreConfig {
        x_dim: 4,
        y_dim: 3,
        hidden: 8,
        heads: 2,
        word: 6,
        mem_words: 16,
        k: 3,
        ann: AnnKind::Linear,
        row_format,
        seed: 7,
        ..CoreConfig::default()
    }
}

fn model_with(row_format: RowFormat) -> Arc<dyn InferModel> {
    let cfg = core_cfg(row_format);
    let mut rng = Rng::new(cfg.seed);
    build_infer_model(CoreKind::Sam, &cfg, &mut rng, None)
}

fn durable_cfg(dir: &PathBuf) -> SessionConfig {
    SessionConfig {
        spill_dir: Some(dir.clone()),
        idle_expiry: Duration::from_millis(0),
        ..SessionConfig::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sam-durability-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn inputs(n: usize, salt: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0xD0_0D ^ salt);
    (0..n)
        .map(|_| (0..4).map(|_| (rng.next_u64() % 1000) as f32 / 500.0 - 1.0).collect())
        .collect()
}

fn bits(y: &[f32]) -> Vec<u32> {
    y.iter().map(|v| v.to_bits()).collect()
}

/// Demote every idle session to disk (expire_idle with a 0 expiry).
fn force_spill(mgr: &SessionManager) {
    std::thread::sleep(Duration::from_millis(3));
    mgr.expire_idle();
}

#[test]
fn kill_and_restart_resumes_bit_identical() {
    let _g = serial();
    for (fmt, tag) in [(RowFormat::F32, "restart-f32"), (RowFormat::Bf16, "restart-bf16")] {
        let dir = tmp_dir(tag);
        let xs = inputs(8, 11);

        // Reference: the same session, never evicted, stepped start to end.
        let reference = SessionManager::new(model_with(fmt), SessionConfig::default());
        let id_ref = reference.open_seeded(Some(42));
        let mut y = Vec::new();
        let mut ref_out: Vec<Vec<u32>> = Vec::new();
        for x in &xs {
            reference.step(id_ref, x, &mut y).unwrap();
            ref_out.push(bits(&y));
        }

        // Durable instance: step half the stream, spill, then "crash"
        // (drop the manager — resident state is gone, the file survives).
        let mgr1 = SessionManager::new(model_with(fmt), durable_cfg(&dir));
        let id = mgr1.open_seeded(Some(42));
        assert_eq!(id, id_ref, "id streams must agree for the comparison");
        for (t, x) in xs[..4].iter().enumerate() {
            mgr1.step(id, x, &mut y).unwrap();
            assert_eq!(bits(&y), ref_out[t], "{tag}: pre-spill t={t} diverged");
        }
        force_spill(&mgr1);
        assert_eq!(mgr1.session_count(), 0);
        assert_eq!(mgr1.spill_stats().0, 1);
        assert!(spill::spill_path(&dir, id).exists());
        drop(mgr1);

        // Cold restart: fresh manager + model, recover, finish the stream.
        let mgr2 = SessionManager::new(model_with(fmt), durable_cfg(&dir));
        let (loaded, corrupt) = mgr2.rehydrate_all();
        assert_eq!((loaded, corrupt), (1, 0), "{tag}: recovery failed");
        assert!(!spill::spill_path(&dir, id).exists(), "consumed spill must be removed");
        for (t, x) in xs[4..].iter().enumerate() {
            mgr2.step(id, x, &mut y).unwrap();
            assert_eq!(bits(&y), ref_out[4 + t], "{tag}: post-restart t={t} not bit-identical");
        }
        // New opens after recovery must not collide with recovered ids.
        assert_ne!(mgr2.open_seeded(Some(1)), id);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn spilled_session_rehydrates_transparently_on_next_step() {
    let _g = serial();
    let dir = tmp_dir("transparent");
    let xs = inputs(6, 22);

    let reference = SessionManager::new(model_with(RowFormat::F32), SessionConfig::default());
    let id_ref = reference.open_seeded(Some(9));
    let mut y = Vec::new();
    let mut ref_out: Vec<Vec<u32>> = Vec::new();
    for x in &xs {
        reference.step(id_ref, x, &mut y).unwrap();
        ref_out.push(bits(&y));
    }

    let mgr = SessionManager::new(model_with(RowFormat::F32), durable_cfg(&dir));
    let id = mgr.open_seeded(Some(9));
    for (t, x) in xs[..3].iter().enumerate() {
        mgr.step(id, x, &mut y).unwrap();
        assert_eq!(bits(&y), ref_out[t]);
    }
    force_spill(&mgr);
    assert_eq!(mgr.session_count(), 0);
    // The caller never sees the demotion: the next step rehydrates.
    for (t, x) in xs[3..].iter().enumerate() {
        mgr.step(id, x, &mut y).unwrap();
        assert_eq!(bits(&y), ref_out[3 + t], "transparent rehydrate t={t} diverged");
    }
    assert_eq!(mgr.spill_stats(), (1, 1, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_and_truncated_spills_are_dropped_never_loaded() {
    let _g = serial();
    let dir = tmp_dir("corrupt");
    let xs = inputs(3, 33);
    let mut y = Vec::new();

    // Byte flip.
    let mgr = SessionManager::new(model_with(RowFormat::F32), durable_cfg(&dir));
    let id = mgr.open_seeded(Some(5));
    for x in &xs {
        mgr.step(id, x, &mut y).unwrap();
    }
    force_spill(&mgr);
    let path = spill::spill_path(&dir, id);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    drop(mgr);

    let mgr2 = SessionManager::new(model_with(RowFormat::F32), durable_cfg(&dir));
    assert_eq!(mgr2.rehydrate_all(), (0, 1), "flipped byte must be a corrupt drop");
    assert!(!path.exists(), "corrupt spill must be deleted, not retried");
    assert!(mgr2.step(id, &xs[0], &mut y).is_err(), "corrupt session must not resurrect");
    assert_eq!(mgr2.spill_stats().2, 1);
    drop(mgr2);

    // Truncation (torn tail) + an orphaned .tmp from a crashed staging
    // write: the truncated file is dropped, the .tmp is ignored entirely.
    let mgr3 = SessionManager::new(model_with(RowFormat::F32), durable_cfg(&dir));
    let id3 = mgr3.open_seeded(Some(6));
    for x in &xs {
        mgr3.step(id3, x, &mut y).unwrap();
    }
    force_spill(&mgr3);
    let path3 = spill::spill_path(&dir, id3);
    let bytes = std::fs::read(&path3).unwrap();
    std::fs::write(&path3, &bytes[..bytes.len() / 2]).unwrap();
    std::fs::write(dir.join("sess-99.spill.tmp"), b"partial staging garbage").unwrap();
    drop(mgr3);

    let mgr4 = SessionManager::new(model_with(RowFormat::F32), durable_cfg(&dir));
    assert_eq!(mgr4.rehydrate_all(), (0, 1), "torn tail must be a corrupt drop");
    assert!(mgr4.step(id3, &xs[0], &mut y).is_err());
    assert!(dir.join("sess-99.spill.tmp").exists(), "stale .tmp is not the manager's to touch");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn int8_snapshot_restores_bit_exact() {
    let _g = serial();
    // Int8 rows carry per-row dequant scales; a snapshot must restore the
    // exact stored bits (set_row_with_scale, not a re-quantization).
    let model = model_with(RowFormat::Int8);
    let xs = inputs(4, 44);
    let mut a = model.open_session(Some(77));
    let mut y = Vec::new();
    for x in &xs {
        model.step(a.as_mut(), x, &mut y);
    }
    let snap = spill::snapshot_session(a.as_mut()).expect("SAM sessions must snapshot");

    // Wire round-trip, then restore into a freshly opened session.
    let meta = spill::SpillMeta { model: "sam".into(), open_seed: Some(77) };
    let (meta2, snap2) = spill::decode_spill(&spill::encode_spill(&meta, &snap)).unwrap();
    assert_eq!(meta2, meta);
    assert_eq!(snap2, snap);
    let mut b = model.open_session(Some(77));
    spill::restore_session(b.as_mut(), &snap2).unwrap();

    let tail = inputs(4, 55);
    let (mut ya, mut yb) = (Vec::new(), Vec::new());
    for x in &tail {
        model.step(a.as_mut(), x, &mut ya);
        model.step(b.as_mut(), x, &mut yb);
        assert_eq!(bits(&ya), bits(&yb), "int8 restore diverged");
    }
}

#[cfg(feature = "fault-inject")]
mod faulted {
    use super::*;
    use sam::serving::{BatchScheduler, SessionError};
    use sam::util::fault::{self, FaultKind};

    #[test]
    fn failed_spill_keeps_victim_resident_and_sheds_opens() {
        let _g = serial();
        fault::clear();
        let dir = tmp_dir("fault-io");
        // Budget of 1 byte: every open beyond the first triggers a demote.
        let session = SessionConfig {
            byte_budget: 1,
            spill_dir: Some(dir.clone()),
            ..SessionConfig::default()
        };
        let mgr = SessionManager::new(model_with(RowFormat::F32), session);

        fault::arm("spill.write", FaultKind::IoError, 0, 1);
        let a = mgr.open_checked(Some(1)).unwrap();
        let b = mgr.open_checked(Some(2)).unwrap(); // demote of a fails
        assert_eq!(mgr.session_count(), 2, "failed spill must never destroy the victim");
        assert_eq!(mgr.spill_failures(), 1);
        assert_eq!(fault::fired_count("spill.write"), 1);

        // Disk failing + over budget → shed, with a retryable error.
        let err = mgr.open_checked(Some(3)).unwrap_err();
        assert!(matches!(err, SessionError::Overloaded { retry_after_ms } if retry_after_ms > 0));
        assert!(err.retryable());
        assert_eq!(mgr.session_count(), 2);

        // Fault passes (count=1 exhausted is already spent; clear anyway):
        // the next budget check spills successfully and opens recover.
        fault::clear();
        let mut y = Vec::new();
        mgr.step(b, &inputs(1, 1)[0], &mut y).unwrap(); // demotes a for real
        assert_eq!(mgr.spill_stats().0, 1);
        assert!(spill::spill_path(&dir, a).exists());
        assert!(mgr.open_checked(Some(4)).is_ok(), "recovered disk must stop shedding");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_write_on_non_atomic_fs_is_detected_on_read() {
        let _g = serial();
        fault::clear();
        let dir = tmp_dir("fault-torn");
        let mgr = SessionManager::new(model_with(RowFormat::F32), durable_cfg(&dir));
        let id = mgr.open_seeded(Some(3));
        let mut y = Vec::new();
        mgr.step(id, &inputs(1, 2)[0], &mut y).unwrap();

        // ShortWrite renames a half-written file into place — the
        // non-atomic-filesystem torn write. The spill "succeeds", so the
        // resident copy is gone; the CRC/END checks must refuse the file.
        fault::arm("spill.write", FaultKind::ShortWrite, 0, 1);
        force_spill(&mgr);
        fault::clear();
        assert_eq!(mgr.session_count(), 0);
        assert!(spill::spill_path(&dir, id).exists());
        assert!(
            mgr.step(id, &inputs(1, 2)[0], &mut y).is_err(),
            "torn spill must never be silently loaded"
        );
        assert_eq!(mgr.spill_stats().2, 1, "torn spill must count as a corrupt drop");
        assert!(!spill::spill_path(&dir, id).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scheduler_panic_mid_tick_errors_instead_of_wedging() {
        let _g = serial();
        fault::clear();
        let mgr = Arc::new(SessionManager::new(
            model_with(RowFormat::F32),
            SessionConfig::default(),
        ));
        let sched = BatchScheduler::start(mgr.clone(), Duration::from_micros(100), 16);
        let id = mgr.open_seeded(Some(8));
        let x = inputs(1, 3)[0].clone();
        assert!(sched.step_blocking(id, x.clone()).is_ok());

        fault::arm("sched.tick", FaultKind::Panic, 0, 1);
        // The injected panic kills the scheduler thread; every in-flight
        // and subsequent request must get an error reply, not a hang —
        // and specifically the retryable SchedulerStopped, NOT
        // NoSuchSession: the session still exists, only the scheduler is
        // gone, so clients must be told to retry rather than to give the
        // session up (regression: the drain paths used to misreport
        // NoSuchSession, which the server renders non-retryable).
        let e1 = sched.step_blocking(id, x.clone()).unwrap_err();
        assert_eq!(e1, SessionError::SchedulerStopped);
        assert!(e1.retryable());
        let e2 = sched.step_blocking(id, x).unwrap_err();
        assert_eq!(e2, SessionError::SchedulerStopped);
        fault::clear();
        sched.stop(); // idempotent on a dead scheduler
    }
}
