//! Determinism of the batched-episode training path (`--batch-fuse B`):
//! the `FusedTrainer` must produce **bit-identical** loss curves,
//! curriculum trajectories and final parameters to the serial `Trainer`
//! at every (workers, batch_fuse) combination, and the batched backward
//! tick must agree with finite differences of the batched forward loss.
//!
//! Why this holds: each lane is a full core replica holding identical
//! parameters, the lane-fused kernels (`gemv_many` / `gemm_rowsweep`)
//! preserve the serial per-lane reduction order exactly, and the trainer
//! reduces per-episode gradients in episode order on the main thread —
//! see `training::batched` docs and DESIGN.md "Batched training".
//!
//! Cores here use `AnnKind::Linear` (content-deterministic reads), the
//! same caveat as rust/tests/parallel_parity.rs: the approximate indexes
//! keep per-(W, B) determinism but not cross-count parity.
//!
//! CI re-runs the matrix with `SAM_TEST_BATCH=4` (see `sam::util::env_batch`),
//! which adds that B to the built-in {1, 2, 8} set.

use sam::cores::{train_tick_backward, train_tick_forward, BatchCore, TrainBatch};
use sam::prelude::*;
use sam::tasks::episode_loss_grad;
use sam::training::TrainLog;
use sam::util::env_batch;

fn core_cfg(task: &dyn Task, seed: u64) -> CoreConfig {
    CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: 12,
        heads: 2,
        word: 8,
        mem_words: 16,
        k: 2,
        k_l: 3,
        ann: AnnKind::Linear,
        seed,
        ..CoreConfig::default()
    }
}

fn train_cfg(seed: u64, batch_fuse: usize) -> TrainConfig {
    TrainConfig {
        lr: 2e-3,
        batch: 5,
        updates: 12,
        log_every: 2,
        seed,
        batch_fuse,
        ..TrainConfig::default()
    }
}

fn curriculum() -> Curriculum {
    // Exponential so curriculum *decisions* (report ordering) are part of
    // the parity check, with a threshold loose enough to actually advance.
    let mut c = Curriculum::exponential(2, 16, 3.0);
    c.patience = 4;
    c
}

/// The built-in lane counts plus CI's `SAM_TEST_BATCH` override, if any.
fn lane_counts() -> Vec<usize> {
    let mut bs = vec![1usize, 2, 8];
    if let Some(extra) = env_batch() {
        if !bs.contains(&extra) {
            bs.push(extra);
        }
    }
    bs
}

fn run_serial(kind: CoreKind, seed: u64) -> (TrainLog, Vec<f32>) {
    let task = CopyTask::new(4);
    let cfg = core_cfg(&task, seed);
    let mut rng = Rng::new(seed);
    let core = build_core(kind, &cfg, &mut rng);
    let mut t = Trainer::new(core, Box::new(RmsProp::new(2e-3)), train_cfg(seed, 1));
    let mut cur = curriculum();
    let log = t.run(&task, &mut cur);
    let params = t.core.save_values();
    (log, params)
}

fn run_fused(kind: CoreKind, seed: u64, workers: usize, b: usize) -> (TrainLog, Vec<f32>) {
    let task = CopyTask::new(4);
    let cfg = core_cfg(&task, seed);
    let mut ft =
        FusedTrainer::new(kind, &cfg, workers, Box::new(RmsProp::new(2e-3)), train_cfg(seed, b));
    let mut cur = curriculum();
    let log = ft.run(&task, &mut cur);
    let (mut core, _) = ft.into_primary();
    let params = core.save_values();
    (log, params)
}

fn assert_logs_bit_identical(a: &TrainLog, b: &TrainLog, what: &str) {
    assert_eq!(a.total_episodes, b.total_episodes, "{what}: episode counts");
    assert_eq!(a.final_level, b.final_level, "{what}: final curriculum level");
    assert_eq!(a.points.len(), b.points.len(), "{what}: log point counts");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.update, pb.update, "{what}: update index");
        assert_eq!(
            pa.loss.to_bits(),
            pb.loss.to_bits(),
            "{what}: loss differs at update {} ({} vs {})",
            pa.update,
            pa.loss,
            pb.loss
        );
        assert_eq!(
            pa.errors.to_bits(),
            pb.errors.to_bits(),
            "{what}: errors differ at update {}",
            pa.update
        );
        assert_eq!(pa.level, pb.level, "{what}: curriculum level at update {}", pa.update);
    }
}

fn assert_params_bit_identical(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: param counts");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: param[{i}] {x} vs {y}");
    }
}

fn parity_matrix(kind: CoreKind, seed: u64, name: &str) {
    let (serial_log, serial_params) = run_serial(kind, seed);
    for b in lane_counts() {
        for workers in [1usize, 4] {
            let what = format!("{name} x{workers} b{b}");
            let (log, params) = run_fused(kind, seed, workers, b);
            assert_logs_bit_identical(&serial_log, &log, &what);
            assert_params_bit_identical(&serial_params, &params, &what);
        }
    }
}

#[test]
fn sam_batched_all_lane_and_worker_counts_bit_identical() {
    parity_matrix(CoreKind::Sam, 42, "sam");
}

#[test]
fn sdnc_batched_all_lane_and_worker_counts_bit_identical() {
    parity_matrix(CoreKind::Sdnc, 9, "sdnc");
}

#[test]
fn batched_training_actually_learns() {
    // Guard against a parity fix that silently zeroes the gradients: the
    // fused run must still reduce the loss.
    let (log, _) = run_fused(CoreKind::Sam, 11, 2, 4);
    assert!(log.points.len() >= 2);
    assert!(
        log.best_loss() <= log.points[0].loss,
        "no learning signal: {:?}",
        log.points.iter().map(|p| p.loss).collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------------
// Finite-difference check of the batched backward ticks
// ---------------------------------------------------------------------------
//
// The fused kernels stream lane 0's weights across every lane, so a
// parameter perturbation must be loaded into ALL lanes; the derivative of
// the summed batch loss w.r.t. one shared parameter is then the sum of the
// per-lane analytic gradients at that index.


/// Total batched loss over the group at the given parameters: forward
/// ticks only, tape discarded via rollback (the eval-only protocol).
fn batched_loss<C: BatchCore>(
    lanes: &mut [C],
    batch: &mut TrainBatch,
    eps: &[Episode],
    flat: &[f32],
) -> f64 {
    let n = eps.len();
    let lanes = &mut lanes[..n];
    for lane in lanes.iter_mut() {
        lane.load_values(flat);
        lane.zero_grads();
        lane.reset();
    }
    let t_max = eps.iter().map(|ep| ep.inputs.len()).max().unwrap_or(0);
    let mut total = 0.0f64;
    let mut xs: Vec<Option<&[f32]>> = Vec::with_capacity(n);
    for t in 0..t_max {
        xs.clear();
        xs.extend(eps.iter().map(|ep| ep.inputs.get(t).map(|v| v.as_slice())));
        train_tick_forward(lanes, batch, &xs);
        for (l, ep) in eps.iter().enumerate() {
            if t < ep.inputs.len() {
                let (lo, _) = episode_loss_grad(ep, t, batch.y_row(l));
                total += lo as f64;
            }
        }
    }
    for lane in lanes.iter_mut() {
        lane.rollback();
        lane.end_episode();
    }
    total
}

/// Per-lane analytic gradients of the batched loss: the full
/// forward-then-reverse tick protocol of `FusedLanes::run_group`.
fn batched_grads<C: BatchCore>(
    lanes: &mut [C],
    batch: &mut TrainBatch,
    eps: &[Episode],
    flat: &[f32],
) -> Vec<Vec<f32>> {
    let n = eps.len();
    let lanes = &mut lanes[..n];
    let y_dim = lanes[0].y_dim();
    for lane in lanes.iter_mut() {
        lane.load_values(flat);
        lane.zero_grads();
        lane.reset();
    }
    let t_max = eps.iter().map(|ep| ep.inputs.len()).max().unwrap_or(0);
    let mut dys: Vec<Vec<Vec<f32>>> = (0..n).map(|_| Vec::new()).collect();
    let mut xs: Vec<Option<&[f32]>> = Vec::with_capacity(n);
    for t in 0..t_max {
        xs.clear();
        xs.extend(eps.iter().map(|ep| ep.inputs.get(t).map(|v| v.as_slice())));
        train_tick_forward(lanes, batch, &xs);
        for (l, ep) in eps.iter().enumerate() {
            if t < ep.inputs.len() {
                let (_, dy) = episode_loss_grad(ep, t, batch.y_row(l));
                dys[l].push(dy);
            }
        }
    }
    let mut active: Vec<bool> = Vec::with_capacity(n);
    for t in (0..t_max).rev() {
        active.clear();
        active.extend(eps.iter().map(|ep| t < ep.inputs.len()));
        batch.stage_dy(n, y_dim);
        for (l, ep) in eps.iter().enumerate() {
            if t < ep.inputs.len() {
                batch.dy_row_mut(l).copy_from_slice(&dys[l][t]);
            }
        }
        train_tick_backward(lanes, batch, &active);
    }
    lanes
        .iter_mut()
        .map(|lane| {
            let g = lane.save_grads();
            lane.end_episode();
            g
        })
        .collect()
}

/// Same failure-fraction scheme as rust/tests/grad_check.rs: f32 forward
/// cancellation noise and discrete structure (ANN top-K, LRA argmin)
/// flipping under the FD perturbation account for a tolerated few, while
/// a systematic backward-tick bug fails essentially every probe.
fn grad_check<C: BatchCore>(mut lanes: Vec<C>, fd_eps: f32, tol: f64, name: &str) {
    let task = CopyTask::new(4);
    let mut rng = Rng::new(5);
    // Ragged lengths so the idle-lane legs of both ticks are exercised.
    let eps: Vec<Episode> =
        (0..lanes.len()).map(|i| task.sample(2 + i, &mut rng)).collect();
    let mut batch = TrainBatch::new();
    let flat = lanes[0].save_values();
    let grads = batched_grads(&mut lanes, &mut batch, &eps, &flat);
    let n = flat.len();
    assert!(grads.iter().all(|g| g.len() == n));

    let probes = 16usize;
    let mut checked = 0usize;
    let mut failed = 0usize;
    for s in 0..probes {
        // Indices spread across the whole parameter vector (cell, head and
        // output projections all land in the sample).
        let idx = s * (n - 1) / (probes - 1);
        let mut up = flat.clone();
        up[idx] += fd_eps;
        let mut dn = flat.clone();
        dn[idx] -= fd_eps;
        let lp = batched_loss(&mut lanes, &mut batch, &eps, &up);
        let lm = batched_loss(&mut lanes, &mut batch, &eps, &dn);
        let fd = (lp - lm) / (2.0 * fd_eps as f64);
        // The fused kernels stream shared weights, so the derivative of
        // the summed batch loss is the SUM of per-lane gradients here.
        let analytic: f64 = grads.iter().map(|g| g[idx] as f64).sum();
        if fd.abs() < 1e-3 && analytic.abs() < 1e-3 {
            continue; // both negligible: nothing to compare at f32 precision
        }
        checked += 1;
        let denom = fd.abs().max(analytic.abs()).max(5e-2);
        if (fd - analytic).abs() / denom > tol {
            eprintln!("{name}: param[{idx}] analytic {analytic:.6} vs FD {fd:.6}");
            failed += 1;
        }
    }
    assert!(checked >= 6, "{name}: too few non-trivial FD probes ({checked})");
    assert!(
        failed * 8 <= checked,
        "{name}: {failed}/{checked} batched FD probes failed (allowed 1/8)"
    );
}

#[test]
fn sam_batched_backward_matches_finite_differences() {
    let task = CopyTask::new(4);
    let cfg = core_cfg(&task, 31);
    let lanes: Vec<sam::cores::sam::SamCore> =
        (0..3).map(|_| sam::cores::sam::SamCore::new(&cfg, &mut Rng::new(31))).collect();
    grad_check(lanes, 5e-3, 0.2, "sam");
}

#[test]
fn sdnc_batched_backward_matches_finite_differences() {
    let task = CopyTask::new(4);
    let cfg = core_cfg(&task, 33);
    let lanes: Vec<sam::cores::sdnc::SdncCore> =
        (0..3).map(|_| sam::cores::sdnc::SdncCore::new(&cfg, &mut Rng::new(33))).collect();
    grad_check(lanes, 1e-2, 0.25, "sdnc");
}
