//! ANN correctness: KdForest, LSH and HNSW top-k results must overlap the
//! exact brute-force cosine top-k (LinearIndex) above a recall threshold, on
//! random key sets and across rebuild boundaries.
//!
//! Queries are sampled *near stored points* — the SAM regime (§3.5):
//! read queries are learned to point at stored memories. Uniformly random
//! queries in high dimension are the known worst case for space-partition
//! indexes and are not the workload.

use sam::ann::{AnnIndex, HnswIndex, KdForest, LinearIndex, LshIndex};
use sam::util::rng::Rng;

fn random_points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.normal()).collect())
        .collect()
}

/// Queries perturbed around stored points.
fn near_queries(pts: &[Vec<f32>], count: usize, noise: f32, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|qi| {
            pts[(qi * 13) % pts.len()]
                .iter()
                .map(|x| x + noise * rng.normal())
                .collect()
        })
        .collect()
}

/// recall@k of `idx` against the exact index.
fn recall(
    idx: &mut dyn AnnIndex,
    exact: &mut LinearIndex,
    queries: &[Vec<f32>],
    k: usize,
) -> f64 {
    let mut hit = 0usize;
    let mut total = 0usize;
    for q in queries {
        let approx: std::collections::HashSet<usize> =
            idx.query(q, k).into_iter().map(|(i, _)| i).collect();
        for (i, _) in exact.query(q, k) {
            total += 1;
            if approx.contains(&i) {
                hit += 1;
            }
        }
    }
    hit as f64 / total.max(1) as f64
}

const RECALL_THRESHOLD: f64 = 0.7;

#[test]
fn kdforest_recall_across_rebuild_boundaries() {
    let (n, dim, k) = (512, 16, 4);
    let pts = random_points(n, dim, 11);
    // rebuild_every = 64 → the insert stream crosses several automatic
    // rebuild boundaries; recall must hold straight after the build.
    let mut forest = KdForest::new(n, dim, 4, 128, 64, 1);
    let mut exact = LinearIndex::new(n, dim);
    for (i, p) in pts.iter().enumerate() {
        forest.insert(i, p);
        exact.insert(i, p);
    }
    let queries = near_queries(&pts, 48, 0.1, 99);
    let r = recall(&mut forest, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "kd recall@{k} after online inserts = {r}");

    // An explicit rebuild must not lose points or recall.
    forest.rebuild();
    assert_eq!(forest.len(), n);
    let r = recall(&mut forest, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "kd recall@{k} after explicit rebuild = {r}");

    // A wave of updates (moving a third of the points) crossing another
    // rebuild boundary: the index must track the moved contents.
    let moved = random_points(n / 3, dim, 12);
    for (i, p) in moved.iter().enumerate() {
        forest.update(i, p);
        exact.update(i, p);
    }
    let mut all: Vec<Vec<f32>> = moved;
    all.extend_from_slice(&pts[n / 3..]);
    let queries = near_queries(&all, 48, 0.1, 100);
    let r = recall(&mut forest, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "kd recall@{k} after update wave = {r}");
}

#[test]
fn lsh_recall_across_rebuild_boundaries() {
    let (n, dim, k) = (512, 32, 4);
    let pts = random_points(n, dim, 21);
    let mut lsh = LshIndex::new(n, dim, 12, 10, 96, 2);
    let mut exact = LinearIndex::new(n, dim);
    for (i, p) in pts.iter().enumerate() {
        lsh.insert(i, p);
        exact.insert(i, p);
    }
    let queries = near_queries(&pts, 48, 0.1, 77);
    let r = recall(&mut lsh, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "lsh recall@{k} = {r}");

    // rebuild() rehashes/compacts buckets; contents and recall must survive.
    lsh.rebuild();
    assert_eq!(lsh.len(), n);
    let r = recall(&mut lsh, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "lsh recall@{k} after rebuild = {r}");

    let moved = random_points(n / 3, dim, 22);
    for (i, p) in moved.iter().enumerate() {
        lsh.update(i, p);
        exact.update(i, p);
    }
    let mut all: Vec<Vec<f32>> = moved;
    all.extend_from_slice(&pts[n / 3..]);
    let queries = near_queries(&all, 48, 0.1, 78);
    let r = recall(&mut lsh, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "lsh recall@{k} after update wave = {r}");
}

/// Shared driver for the incremental-maintenance property (the engine's
/// default path): several interleaved `update_row` waves with the full
/// rebuild threshold set far out of reach — recall against brute force
/// must hold after every wave, the rebuild counter must prove the index
/// never fell back to a full rebuild, and `remove_row` must take effect
/// immediately.
fn incremental_waves_hold_recall(
    idx: &mut dyn AnnIndex,
    n: usize,
    dim: usize,
    pts: &[Vec<f32>],
    label: &str,
) {
    let k = 4;
    let mut exact = LinearIndex::new(n, dim);
    for (i, p) in pts.iter().enumerate() {
        idx.insert(i, p);
        exact.insert(i, p);
    }
    let builds_after_load = idx.full_rebuilds();
    let mut current: Vec<Vec<f32>> = pts.to_vec();
    for wave in 0..4u64 {
        let moved = random_points(n / 4, dim, 1000 + wave);
        for (j, p) in moved.iter().enumerate() {
            // Interleave moved ids across the key space so every wave
            // touches every region of the index.
            let id = (j * 4 + wave as usize) % n;
            idx.update_row(id, p);
            exact.update_row(id, p);
            current[id] = p.clone();
        }
        let queries = near_queries(&current, 32, 0.1, 2000 + wave);
        let r = recall(&mut *idx, &mut exact, &queries, k);
        assert!(
            r >= RECALL_THRESHOLD,
            "{label} incremental recall@{k} after wave {wave} = {r}"
        );
    }
    assert_eq!(
        idx.full_rebuilds(),
        builds_after_load,
        "{label}: update waves must stay on the incremental path (no full rebuilds)"
    );
    // remove_row must hide the id from queries without any rebuild.
    idx.remove_row(0);
    let res = idx.query(&current[0], k);
    assert!(res.iter().all(|&(i, _)| i != 0), "{label}: remove_row leaked id 0");
    assert_eq!(idx.len(), n - 1);
    assert_eq!(idx.full_rebuilds(), builds_after_load);
}

#[test]
fn kdforest_incremental_updates_without_rebuilds() {
    let (n, dim) = (256, 16);
    let pts = random_points(n, dim, 41);
    // rebuild_every far above the op count: the only full build is the
    // initial one (asserted inside the driver via full_rebuilds()).
    let mut forest = KdForest::new(n, dim, 4, 128, 1_000_000, 3);
    incremental_waves_hold_recall(&mut forest, n, dim, &pts, "kd");
}

#[test]
fn lsh_incremental_updates_without_rebuilds() {
    let (n, dim) = (256, 32);
    let pts = random_points(n, dim, 51);
    // 256 loads + 4×64 updates×2 ops each = 768 ops, well under the index's
    // amortized compaction threshold (8·n), so the whole run stays
    // incremental.
    let mut lsh = LshIndex::new(n, dim, 12, 10, 96, 4);
    incremental_waves_hold_recall(&mut lsh, n, dim, &pts, "lsh");
}

#[test]
fn hnsw_incremental_updates_without_rebuilds() {
    let (n, dim) = (256, 32);
    let pts = random_points(n, dim, 61);
    // HNSW never auto-rebuilds: update_row relinks in place (the node's
    // level is a pure hash of its id) and remove_row repairs neighbors, so
    // the driver's full_rebuilds() assertion pins the counter at 0.
    let mut h = HnswIndex::with_defaults(n, dim, 5);
    incremental_waves_hold_recall(&mut h, n, dim, &pts, "hnsw");
    assert_eq!(h.full_rebuilds(), 0, "hnsw must never fall back to a full rebuild");
}

/// The tentpole recall gate: at the paper's W=64 word size, HNSW recall@16
/// against brute force must reach 0.95. Full N=100k only in release builds
/// (tier-1 `cargo test -q` is a debug build; graph construction there would
/// dominate the suite), a 2048-row leg keeps the property exercised in debug.
#[test]
fn hnsw_recall_at_16_vs_exact() {
    let (dim, k) = (64usize, 16usize);
    let n = if cfg!(debug_assertions) { 2048 } else { 100_000 };
    let pts = random_points(n, dim, 71);
    let mut h = HnswIndex::with_defaults(n, dim, 6);
    // Recall-tuned search width: ef trades latency for recall; the speed
    // bench measures the default (64), this gate measures quality headroom.
    h.ef_search = 192;
    let mut exact = LinearIndex::new(n, dim);
    for (i, p) in pts.iter().enumerate() {
        h.insert(i, p);
        exact.insert(i, p);
    }
    let queries = near_queries(&pts, 64, 0.1, 72);
    let r = recall(&mut h, &mut exact, &queries, k);
    assert!(r >= 0.95, "hnsw recall@{k} at N={n} = {r}");
}

/// Row-compaction recall gate: bf16-stored rows quantize the unit vectors
/// the linear scan ranks, so recall@16 against the f32 scan may degrade by
/// at most 0.01 at the paper's W=64 word size. Full N=100k only in release
/// builds (same tier-1 rationale as `hnsw_recall_at_16_vs_exact`); a
/// 2048-row leg keeps the property exercised in debug.
#[test]
fn bf16_rows_recall_at_16_degrades_at_most_1pct() {
    use sam::tensor::rowcodec::RowFormat;
    let (dim, k) = (64usize, 16usize);
    let n = if cfg!(debug_assertions) { 2048 } else { 100_000 };
    let pts = random_points(n, dim, 81);
    let mut exact = LinearIndex::new(n, dim);
    let mut compact = LinearIndex::with_format(n, dim, RowFormat::Bf16);
    for (i, p) in pts.iter().enumerate() {
        exact.insert(i, p);
        compact.insert(i, p);
    }
    let queries = near_queries(&pts, 64, 0.1, 82);
    let r = recall(&mut compact, &mut exact, &queries, k);
    assert!(r >= 0.99, "bf16 rows recall@{k} at N={n} = {r} (must stay within 0.01 of f32)");
}

/// Exact cosine top-k over the engine's rows by brute force (ground truth
/// for the recall comparison; O(N) per query).
fn exact_topk(e: &sam::memory::sharded::ShardedMemoryEngine, q: &[f32], k: usize) -> Vec<usize> {
    let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
    let qn = dot(q, q).sqrt().max(1e-12);
    let mut best: Vec<(f32, usize)> = Vec::with_capacity(k + 1);
    for i in 0..e.n() {
        let row = e.row(i);
        let rn = dot(row, row).sqrt().max(1e-12);
        let cos = dot(q, row) / (qn * rn);
        if best.len() < k || cos > best.last().unwrap().0 {
            let pos = best.partition_point(|&(c, _)| c >= cos);
            best.insert(pos, (cos, i));
            if best.len() > k {
                best.pop();
            }
        }
    }
    best.into_iter().map(|(_, i)| i).collect()
}

/// The scale acceptance check: at N = 1M, the S-sharded merged LSH query
/// must recall essentially as much of the exact top-K as one monolithic
/// LSH index. Note the shards are *independent* hash structures (each
/// shard's ANN seed is mixed with its id), so the merged candidate set is
/// NOT a strict superset of the single index's — merging S per-shard
/// top-K lists typically widens the effective candidate pool (S·K
/// candidates cut to K), but a strict `>=` is not guaranteed structure-
/// by-structure; the assertion therefore allows a small epsilon and
/// additionally enforces an absolute floor.
///
/// `#[ignore]`-gated: this is a release-scale test (~3-5 s with `--release`,
/// minutes in debug). CI's bench-smoke step runs it via
/// `cargo test --release -q -- --ignored million`; it also honors
/// `SAM_TEST_SHARDS` for the sharded side (default 4).
#[test]
#[ignore = "million-row scale: run with cargo test --release -- --ignored million"]
fn million_row_sharded_recall_at_least_single_index() {
    use sam::memory::sharded::ShardedMemoryEngine;
    use sam::prelude::AnnKind;

    if cfg!(debug_assertions) {
        eprintln!("million_row_sharded_recall: skipping in a debug build (release-only)");
        return;
    }
    let (n, dim, k) = (1usize << 20, 16usize, 8usize);
    let s = sam::util::env_shards().unwrap_or(4);
    let (mem_seed, ann_seed) = (99u64, 100u64);
    let mut single = ShardedMemoryEngine::new_sparse_from_seeds(
        n, dim, k, 0.005, AnnKind::Lsh, mem_seed, ann_seed, 1,
    );
    let mut sharded = ShardedMemoryEngine::new_sparse_from_seeds(
        n, dim, k, 0.005, AnnKind::Lsh, mem_seed, ann_seed, s,
    );
    // Queries near stored rows (the SAM regime; see module docs). Contents
    // of both engines are bit-identical by seeding, so one ground truth
    // serves both.
    let mut rng = Rng::new(7);
    let queries: Vec<Vec<f32>> = (0..16)
        .map(|qi| {
            let base = single.row((qi * 65_537) % n).to_vec();
            base.iter().map(|x| x + 0.1 * x.abs().max(0.002) * rng.normal()).collect()
        })
        .collect();
    let (mut hit1, mut hits, mut total) = (0usize, 0usize, 0usize);
    for q in &queries {
        let truth = exact_topk(&single, q, k);
        let r1: std::collections::HashSet<usize> = single
            .content_read_many(&[(q.clone(), 0.5)])
            .remove(0)
            .rows
            .into_iter()
            .collect();
        let rs: std::collections::HashSet<usize> = sharded
            .content_read_many(&[(q.clone(), 0.5)])
            .remove(0)
            .rows
            .into_iter()
            .collect();
        for t in truth {
            total += 1;
            hit1 += r1.contains(&t) as usize;
            hits += rs.contains(&t) as usize;
        }
    }
    let (r1, rs) = (hit1 as f64 / total as f64, hits as f64 / total as f64);
    eprintln!("million-row recall@{k}: single={r1:.3} sharded(S={s})={rs:.3}");
    assert!(
        rs + 0.02 >= r1,
        "merged sharded recall ({rs:.3}) materially below single-index recall ({r1:.3})"
    );
    assert!(rs >= 0.3, "sharded recall implausibly low: {rs:.3}");
}

/// HNSW twin of the million-row acceptance check above: the S-sharded merged
/// HNSW query must recall essentially as much of the exact top-K as one
/// monolithic HNSW graph (same epsilon rationale — shards are independent
/// graphs whose seeds are mixed with the shard id, and the merge widens the
/// candidate pool from K to S·K before cutting back).
#[test]
#[ignore = "million-row scale: run with cargo test --release -- --ignored million"]
fn million_row_sharded_recall_hnsw_vs_single() {
    use sam::memory::sharded::ShardedMemoryEngine;
    use sam::prelude::AnnKind;

    if cfg!(debug_assertions) {
        eprintln!("million_row_sharded_recall_hnsw: skipping in a debug build (release-only)");
        return;
    }
    let (n, dim, k) = (1usize << 20, 16usize, 8usize);
    let s = sam::util::env_shards().unwrap_or(4);
    let (mem_seed, ann_seed) = (199u64, 200u64);
    let mut single = ShardedMemoryEngine::new_sparse_from_seeds(
        n, dim, k, 0.005, AnnKind::Hnsw, mem_seed, ann_seed, 1,
    );
    let mut sharded = ShardedMemoryEngine::new_sparse_from_seeds(
        n, dim, k, 0.005, AnnKind::Hnsw, mem_seed, ann_seed, s,
    );
    let mut rng = Rng::new(8);
    let queries: Vec<Vec<f32>> = (0..16)
        .map(|qi| {
            let base = single.row((qi * 65_537) % n).to_vec();
            base.iter().map(|x| x + 0.1 * x.abs().max(0.002) * rng.normal()).collect()
        })
        .collect();
    let (mut hit1, mut hits, mut total) = (0usize, 0usize, 0usize);
    for q in &queries {
        let truth = exact_topk(&single, q, k);
        let r1: std::collections::HashSet<usize> = single
            .content_read_many(&[(q.clone(), 0.5)])
            .remove(0)
            .rows
            .into_iter()
            .collect();
        let rs: std::collections::HashSet<usize> = sharded
            .content_read_many(&[(q.clone(), 0.5)])
            .remove(0)
            .rows
            .into_iter()
            .collect();
        for t in truth {
            total += 1;
            hit1 += r1.contains(&t) as usize;
            hits += rs.contains(&t) as usize;
        }
    }
    let (r1, rs) = (hit1 as f64 / total as f64, hits as f64 / total as f64);
    eprintln!("million-row hnsw recall@{k}: single={r1:.3} sharded(S={s})={rs:.3}");
    assert!(
        rs + 0.02 >= r1,
        "merged sharded hnsw recall ({rs:.3}) materially below single-graph recall ({r1:.3})"
    );
    assert!(rs >= 0.3, "sharded hnsw recall implausibly low: {rs:.3}");
}

#[test]
fn exact_self_queries_always_hit() {
    // Self-queries (noise 0) are the floor case: the stored point itself
    // must come back as the top-1 with cosine ≈ 1 for every backend.
    let (n, dim) = (128, 16);
    let pts = random_points(n, dim, 31);
    let mut forest = KdForest::with_defaults(n, dim, 3);
    let mut lsh = LshIndex::with_defaults(n, dim, 4);
    let mut hnsw = HnswIndex::with_defaults(n, dim, 5);
    for (i, p) in pts.iter().enumerate() {
        forest.insert(i, p);
        lsh.insert(i, p);
        hnsw.insert(i, p);
    }
    for i in (0..n).step_by(13) {
        let rf = forest.query(&pts[i], 1);
        assert_eq!(rf[0].0, i, "kd self-query {i}");
        assert!((rf[0].1 - 1.0).abs() < 1e-4);
        let rl = lsh.query(&pts[i], 1);
        assert_eq!(rl[0].0, i, "lsh self-query {i}");
        assert!((rl[0].1 - 1.0).abs() < 1e-4);
        let rh = hnsw.query(&pts[i], 1);
        assert_eq!(rh[0].0, i, "hnsw self-query {i}");
        assert!((rh[0].1 - 1.0).abs() < 1e-4);
    }
}
