//! ANN correctness: KdForest and LSH top-k results must overlap the exact
//! brute-force cosine top-k (LinearIndex) above a recall threshold, on
//! random key sets and across rebuild boundaries.
//!
//! Queries are sampled *near stored points* — the SAM regime (§3.5):
//! read queries are learned to point at stored memories. Uniformly random
//! queries in high dimension are the known worst case for space-partition
//! indexes and are not the workload.

use sam::ann::{AnnIndex, KdForest, LinearIndex, LshIndex};
use sam::util::rng::Rng;

fn random_points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.normal()).collect())
        .collect()
}

/// Queries perturbed around stored points.
fn near_queries(pts: &[Vec<f32>], count: usize, noise: f32, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|qi| {
            pts[(qi * 13) % pts.len()]
                .iter()
                .map(|x| x + noise * rng.normal())
                .collect()
        })
        .collect()
}

/// recall@k of `idx` against the exact index.
fn recall(
    idx: &mut dyn AnnIndex,
    exact: &mut LinearIndex,
    queries: &[Vec<f32>],
    k: usize,
) -> f64 {
    let mut hit = 0usize;
    let mut total = 0usize;
    for q in queries {
        let approx: std::collections::HashSet<usize> =
            idx.query(q, k).into_iter().map(|(i, _)| i).collect();
        for (i, _) in exact.query(q, k) {
            total += 1;
            if approx.contains(&i) {
                hit += 1;
            }
        }
    }
    hit as f64 / total.max(1) as f64
}

const RECALL_THRESHOLD: f64 = 0.7;

#[test]
fn kdforest_recall_across_rebuild_boundaries() {
    let (n, dim, k) = (512, 16, 4);
    let pts = random_points(n, dim, 11);
    // rebuild_every = 64 → the insert stream crosses several automatic
    // rebuild boundaries; recall must hold straight after the build.
    let mut forest = KdForest::new(n, dim, 4, 128, 64, 1);
    let mut exact = LinearIndex::new(n, dim);
    for (i, p) in pts.iter().enumerate() {
        forest.insert(i, p);
        exact.insert(i, p);
    }
    let queries = near_queries(&pts, 48, 0.1, 99);
    let r = recall(&mut forest, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "kd recall@{k} after online inserts = {r}");

    // An explicit rebuild must not lose points or recall.
    forest.rebuild();
    assert_eq!(forest.len(), n);
    let r = recall(&mut forest, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "kd recall@{k} after explicit rebuild = {r}");

    // A wave of updates (moving a third of the points) crossing another
    // rebuild boundary: the index must track the moved contents.
    let moved = random_points(n / 3, dim, 12);
    for (i, p) in moved.iter().enumerate() {
        forest.update(i, p);
        exact.update(i, p);
    }
    let mut all: Vec<Vec<f32>> = moved;
    all.extend_from_slice(&pts[n / 3..]);
    let queries = near_queries(&all, 48, 0.1, 100);
    let r = recall(&mut forest, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "kd recall@{k} after update wave = {r}");
}

#[test]
fn lsh_recall_across_rebuild_boundaries() {
    let (n, dim, k) = (512, 32, 4);
    let pts = random_points(n, dim, 21);
    let mut lsh = LshIndex::new(n, dim, 12, 10, 96, 2);
    let mut exact = LinearIndex::new(n, dim);
    for (i, p) in pts.iter().enumerate() {
        lsh.insert(i, p);
        exact.insert(i, p);
    }
    let queries = near_queries(&pts, 48, 0.1, 77);
    let r = recall(&mut lsh, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "lsh recall@{k} = {r}");

    // rebuild() rehashes/compacts buckets; contents and recall must survive.
    lsh.rebuild();
    assert_eq!(lsh.len(), n);
    let r = recall(&mut lsh, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "lsh recall@{k} after rebuild = {r}");

    let moved = random_points(n / 3, dim, 22);
    for (i, p) in moved.iter().enumerate() {
        lsh.update(i, p);
        exact.update(i, p);
    }
    let mut all: Vec<Vec<f32>> = moved;
    all.extend_from_slice(&pts[n / 3..]);
    let queries = near_queries(&all, 48, 0.1, 78);
    let r = recall(&mut lsh, &mut exact, &queries, k);
    assert!(r >= RECALL_THRESHOLD, "lsh recall@{k} after update wave = {r}");
}

/// Shared driver for the incremental-maintenance property (the engine's
/// default path): several interleaved `update_row` waves with the full
/// rebuild threshold set far out of reach — recall against brute force
/// must hold after every wave, the rebuild counter must prove the index
/// never fell back to a full rebuild, and `remove_row` must take effect
/// immediately.
fn incremental_waves_hold_recall(
    idx: &mut dyn AnnIndex,
    n: usize,
    dim: usize,
    pts: &[Vec<f32>],
    label: &str,
) {
    let k = 4;
    let mut exact = LinearIndex::new(n, dim);
    for (i, p) in pts.iter().enumerate() {
        idx.insert(i, p);
        exact.insert(i, p);
    }
    let builds_after_load = idx.full_rebuilds();
    let mut current: Vec<Vec<f32>> = pts.to_vec();
    for wave in 0..4u64 {
        let moved = random_points(n / 4, dim, 1000 + wave);
        for (j, p) in moved.iter().enumerate() {
            // Interleave moved ids across the key space so every wave
            // touches every region of the index.
            let id = (j * 4 + wave as usize) % n;
            idx.update_row(id, p);
            exact.update_row(id, p);
            current[id] = p.clone();
        }
        let queries = near_queries(&current, 32, 0.1, 2000 + wave);
        let r = recall(&mut *idx, &mut exact, &queries, k);
        assert!(
            r >= RECALL_THRESHOLD,
            "{label} incremental recall@{k} after wave {wave} = {r}"
        );
    }
    assert_eq!(
        idx.full_rebuilds(),
        builds_after_load,
        "{label}: update waves must stay on the incremental path (no full rebuilds)"
    );
    // remove_row must hide the id from queries without any rebuild.
    idx.remove_row(0);
    let res = idx.query(&current[0], k);
    assert!(res.iter().all(|&(i, _)| i != 0), "{label}: remove_row leaked id 0");
    assert_eq!(idx.len(), n - 1);
    assert_eq!(idx.full_rebuilds(), builds_after_load);
}

#[test]
fn kdforest_incremental_updates_without_rebuilds() {
    let (n, dim) = (256, 16);
    let pts = random_points(n, dim, 41);
    // rebuild_every far above the op count: the only full build is the
    // initial one (asserted inside the driver via full_rebuilds()).
    let mut forest = KdForest::new(n, dim, 4, 128, 1_000_000, 3);
    incremental_waves_hold_recall(&mut forest, n, dim, &pts, "kd");
}

#[test]
fn lsh_incremental_updates_without_rebuilds() {
    let (n, dim) = (256, 32);
    let pts = random_points(n, dim, 51);
    // 256 loads + 4×64 updates = 512 ops, well under the index's amortized
    // compaction threshold (8·n), so the whole run stays incremental.
    let mut lsh = LshIndex::new(n, dim, 12, 10, 96, 4);
    incremental_waves_hold_recall(&mut lsh, n, dim, &pts, "lsh");
}

#[test]
fn exact_self_queries_always_hit() {
    // Self-queries (noise 0) are the floor case: the stored point itself
    // must come back as the top-1 with cosine ≈ 1 for every backend.
    let (n, dim) = (128, 16);
    let pts = random_points(n, dim, 31);
    let mut forest = KdForest::with_defaults(n, dim, 3);
    let mut lsh = LshIndex::with_defaults(n, dim, 4);
    for (i, p) in pts.iter().enumerate() {
        forest.insert(i, p);
        lsh.insert(i, p);
    }
    for i in (0..n).step_by(13) {
        let rf = forest.query(&pts[i], 1);
        assert_eq!(rf[0].0, i, "kd self-query {i}");
        assert!((rf[0].1 - 1.0).abs() < 1e-4);
        let rl = lsh.query(&pts[i], 1);
        assert_eq!(rl[0].0, i, "lsh self-query {i}");
        assert!((rl[0].1 - 1.0).abs() < 1e-4);
    }
}
