//! Integration tests: the JAX/Pallas AOT artifacts executed via PJRT must
//! agree numerically with the native Rust implementations on identical
//! inputs/weights. This is the cross-check between L1/L2 (python, build
//! time) and L3 (rust, run time).
//!
//! Requires `make artifacts` to have produced `artifacts/*.hlo.txt`; the
//! tests skip (with a notice) when artifacts are absent so `cargo test`
//! stays green on a fresh checkout.

use sam::cores::addressing::content_weights;
use sam::memory::store::MemoryStore;
use sam::nn::lstm::Lstm;
use sam::runtime::{Runtime, Tensor};
use sam::tensor::csr::SparseVec;
use sam::util::json::Json;
use sam::util::rng::Rng;
use std::path::PathBuf;

struct Ctx {
    rt: Runtime,
    cfg: ManifestCfg,
}

#[derive(Debug, Clone, Copy)]
struct ManifestCfg {
    x_dim: usize,
    hidden: usize,
    mem_words: usize,
    word: usize,
    k: usize,
}

fn setup() -> Option<Ctx> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    let manifest = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
    let j = Json::parse(&manifest).ok()?;
    let c = j.get("config")?;
    let get = |k: &str| c.get(k).and_then(|v| v.as_f64()).map(|v| v as usize);
    let cfg = ManifestCfg {
        x_dim: get("x_dim")?,
        hidden: get("hidden")?,
        mem_words: get("mem_words")?,
        word: get("word")?,
        k: get("k")?,
    };
    let mut rt = Runtime::cpu().expect("pjrt cpu client");
    // A load failure means the pjrt backend is unavailable (default build
    // uses the stub runtime, which cannot compile HLO): skip, don't fail —
    // artifacts being present doesn't make the backend present.
    if let Err(e) = rt.load_dir(&dir) {
        eprintln!("SKIP: cannot load artifacts ({e:#}) — build with --features pjrt");
        return None;
    }
    Some(Ctx { rt, cfg })
}

fn assert_close(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= atol + 1e-4 * y.abs().max(x.abs()),
            "{what}[{i}]: rust={x} hlo={y}"
        );
    }
}

fn random_mem(n: usize, w: usize, rng: &mut Rng) -> MemoryStore {
    let mut mem = MemoryStore::zeros(n, w);
    for i in 0..n {
        for v in mem.row_mut(i) {
            *v = rng.normal();
        }
    }
    mem
}

#[test]
fn lstm_cell_matches_rust() {
    let Some(ctx) = setup() else { return };
    let (i_dim, h_dim) = (ctx.cfg.x_dim, ctx.cfg.hidden);
    let mut rng = Rng::new(101);
    let mut lstm = Lstm::new("parity", i_dim, h_dim, &mut rng);
    // Random state + input.
    let x: Vec<f32> = (0..i_dim).map(|_| rng.normal()).collect();
    let h0: Vec<f32> = (0..h_dim).map(|_| rng.normal() * 0.5).collect();
    let c0: Vec<f32> = (0..h_dim).map(|_| rng.normal() * 0.5).collect();
    lstm.h = h0.clone();
    lstm.c = c0.clone();
    let h1 = lstm.step(&x);
    let c1 = lstm.c.clone();

    let out = ctx
        .rt
        .exec(
            "lstm_cell",
            &[
                (&x, &[1, i_dim]),
                (&h0, &[1, h_dim]),
                (&c0, &[1, h_dim]),
                (&lstm.wx.w.data, &[4 * h_dim, i_dim]),
                (&lstm.wh.w.data, &[4 * h_dim, h_dim]),
                (&lstm.b.w.data, &[4 * h_dim]),
            ],
        )
        .expect("exec lstm_cell");
    assert_eq!(out.len(), 2, "lstm_cell returns (h', c')");
    assert_close(&h1, &out[0], 1e-4, "h'");
    assert_close(&c1, &out[1], 1e-4, "c'");
}

#[test]
fn dam_read_matches_rust_dense_content_read() {
    let Some(ctx) = setup() else { return };
    let (n, w) = (ctx.cfg.mem_words, ctx.cfg.word);
    let mut rng = Rng::new(202);
    let mem = random_mem(n, w, &mut rng);
    let q: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
    let beta_raw = 0.7f32;

    // Rust reference: softmax(β·cos) over all N then weighted read.
    let cr = content_weights(&q, beta_raw, &mem, (0..n).collect());
    let mut r_rust = vec![0.0f32; w];
    mem.read_dense(&cr.weights, &mut r_rust);

    // HLO (Pallas online-softmax kernel inside).
    let mut mem_flat = Vec::with_capacity(n * w);
    for i in 0..n {
        mem_flat.extend_from_slice(mem.row(i));
    }
    let out = ctx
        .rt
        .exec(
            "dam_read",
            &[(&q, &[1, w]), (&[beta_raw][..], &[1]), (&mem_flat, &[n, w])],
        )
        .expect("exec dam_read");
    assert_close(&r_rust, &out[0], 2e-4, "dam read");
}

#[test]
fn sam_read_softmax_matches_rust_sparse_read() {
    let Some(ctx) = setup() else { return };
    let (n, w, k) = (ctx.cfg.mem_words, ctx.cfg.word, ctx.cfg.k);
    let mut rng = Rng::new(303);
    let mem = random_mem(n, w, &mut rng);
    let q: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
    let beta_raw = -0.2f32;
    let rows: Vec<usize> = rng.sample_indices(n, k);

    // Rust: content weights over exactly those K rows, then sparse read.
    let cr = content_weights(&q, beta_raw, &mem, rows.clone());
    let wsp = SparseVec::from_pairs(
        cr.rows.iter().copied().zip(cr.weights.iter().copied()).collect(),
    );
    let mut r_rust = vec![0.0f32; w];
    mem.read_sparse(&wsp, &mut r_rust);

    // Two artifacts cover the sparse path: `sam_read` (explicit weights →
    // the Pallas gather kernel) and `sam_read_softmax` (β/cos softmax over
    // the K ANN rows, fully fused). Check both against the rust numerics.
    let mut mem_flat = Vec::with_capacity(n * w);
    for i in 0..n {
        mem_flat.extend_from_slice(mem.row(i));
    }
    let idx: Vec<i32> = rows.iter().map(|&i| i as i32).collect();
    let out = ctx
        .rt
        .exec_tensors(
            "sam_read",
            &[
                Tensor::F32(&mem_flat, &[n, w]),
                Tensor::I32(&idx, &[1, k]),
                Tensor::F32(&cr.weights, &[1, k]),
            ],
        )
        .expect("exec sam_read");
    assert_close(&r_rust, &out[0], 2e-4, "sam sparse read (pallas gather)");

    let out2 = ctx
        .rt
        .exec_tensors(
            "sam_read_softmax",
            &[
                Tensor::F32(&mem_flat, &[n, w]),
                Tensor::I32(&idx, &[1, k]),
                Tensor::F32(&q, &[1, w]),
                Tensor::F32(&[beta_raw], &[1]),
            ],
        )
        .expect("exec sam_read_softmax");
    assert_close(&r_rust, &out2[0], 2e-4, "sam fused softmax read");
    assert_close(&cr.weights, &out2[1], 2e-4, "sam read weights");
}

#[test]
fn dam_step_executes_and_is_stateful() {
    let Some(ctx) = setup() else { return };
    let (i_dim, h_dim, n, w) = (ctx.cfg.x_dim, ctx.cfg.hidden, ctx.cfg.mem_words, ctx.cfg.word);
    let mut rng = Rng::new(404);
    let rand = |len: usize, rng: &mut Rng, s: f32| -> Vec<f32> {
        (0..len).map(|_| rng.normal() * s).collect()
    };
    let x = rand(i_dim, &mut rng, 1.0);
    let h = vec![0.0f32; h_dim];
    let c = vec![0.0f32; h_dim];
    let mem = rand(n * w, &mut rng, 0.1);
    let usage = vec![0.0f32; n];
    let w_read_prev = vec![0.0f32; n];
    let r_prev = vec![0.0f32; w];
    let fan = |f: usize| 1.0 / (f as f32).sqrt();
    let wx = rand(4 * h_dim * (i_dim + w), &mut rng, fan(i_dim + w));
    let wh = rand(4 * h_dim * h_dim, &mut rng, fan(h_dim));
    let b = vec![0.0f32; 4 * h_dim];
    let w_head = rand((2 * w + 3) * h_dim, &mut rng, fan(h_dim));
    let b_head = vec![0.0f32; 2 * w + 3];
    let w_out = rand(w * (h_dim + w), &mut rng, fan(h_dim + w));
    let b_out = vec![0.0f32; w];

    let dims: Vec<Vec<usize>> = vec![
        vec![i_dim],
        vec![h_dim],
        vec![h_dim],
        vec![n, w],
        vec![n],
        vec![n],
        vec![w],
        vec![4 * h_dim, i_dim + w],
        vec![4 * h_dim, h_dim],
        vec![4 * h_dim],
        vec![2 * w + 3, h_dim],
        vec![2 * w + 3],
        vec![w, h_dim + w],
        vec![w],
    ];
    let data: Vec<&[f32]> = vec![
        &x, &h, &c, &mem, &usage, &w_read_prev, &r_prev, &wx, &wh, &b, &w_head, &b_head,
        &w_out, &b_out,
    ];
    let inputs: Vec<(&[f32], &[usize])> =
        data.into_iter().zip(dims.iter().map(|d| d.as_slice())).collect();
    let out = ctx.rt.exec("dam_step", &inputs).expect("exec dam_step");
    // (y, h', c', mem', usage', w_read, r)
    assert_eq!(out.len(), 7);
    assert_eq!(out[0].len(), w);
    assert_eq!(out[3].len(), n * w);
    assert!(out.iter().flatten().all(|v| v.is_finite()));
    // The write must have modified the memory and usage.
    assert_ne!(out[3], mem, "memory should change after a step");
    assert!(out[4].iter().sum::<f32>() > 0.0, "usage should accumulate");
    // Read weights are a distribution over N.
    let wsum: f32 = out[5].iter().sum();
    assert!((wsum - 1.0).abs() < 1e-3, "read weights sum {wsum}");
}
