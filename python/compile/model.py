"""L2: JAX compute cells for the memory cores, calling the L1 Pallas
kernels. These are the functions `aot.py` lowers to HLO text for the Rust
runtime — build-time only, never on the request path.

Cells (all pure functions, parameters as explicit arguments so the Rust
side can feed trained weights):

* ``lstm_cell``        — the controller step (Supp B).
* ``dam_read_cell``    — dense content read via the Pallas online-softmax
                         kernel (eq. 1-2).
* ``sam_read_cell``    — K-sparse read via the Pallas gather kernel (eq. 4);
                         indices come from the Rust ANN.
* ``dam_step_cell``    — a full DAM inference step: controller + heads +
                         dense write + dense read + output. This is the
                         cell the serving example drives per timestep.
"""

import jax.numpy as jnp

from .kernels import content_addressing, ref, sparse_read


def lstm_cell(x, h, c, wx, wh, b):
    """Controller LSTM step (matches rust nn::lstm, forget bias 1.0)."""
    return ref.lstm_cell(x, h, c, wx, wh, b)


def dam_read_cell(q, beta_raw, mem):
    """Dense content read. β = softplus(β̂)+1 as in the Rust cores.
    q: [B,W], beta_raw: [B], mem: [N,W] → read [B,W]."""
    beta = jnp.logaddexp(beta_raw, 0.0) + 1.0  # softplus + 1
    return content_addressing.content_attention(q, beta, mem)


def sam_read_cell(mem, idx, weights):
    """Sparse read of ANN-selected rows. mem: [N,W], idx: [B,K] i32,
    weights: [B,K] → [B,W]."""
    return sparse_read.sparse_read(mem, idx, weights)


def sam_read_softmax_cell(mem, idx, q, beta_raw):
    """Sparse content read as the SAM core computes it: gather the K
    candidate rows, softmax(β·cos) over just those, then the weighted sum
    (all fused by XLA). idx: [B,K] i32 from the Rust ANN."""
    rows = mem[idx]  # [B,K,W]
    nq = jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), ref.NORM_FLOOR)
    nm = jnp.maximum(jnp.linalg.norm(rows, axis=-1), ref.NORM_FLOOR)  # [B,K]
    sims = jnp.einsum("bw,bkw->bk", q, rows) / (nq * nm)
    beta = jnp.logaddexp(beta_raw, 0.0) + 1.0
    logits = beta[:, None] * sims
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    read = jnp.einsum("bk,bkw->bw", w, rows)
    return read, w


def dam_step_cell(
    x, h, c, mem, usage, w_read_prev, r_prev,
    wx, wh, b, w_head, b_head, w_out, b_out,
):
    """One full DAM inference step (single head, batch 1 folded out).

    Mirrors cores::dam forward: controller LSTM on [x, r_prev] → head
    params [q(W), a(W), α̂, γ̂, β̂] → interpolation write with the
    least-used slot → Pallas dense content read → output projection.

    Shapes: x [I], h/c [H], mem [N,W], usage [N], w_read_prev [N], r_prev
    [W]; returns (y, h', c', mem', usage', w_read, r).
    """
    word = mem.shape[1]
    x_in = jnp.concatenate([x, r_prev])[None, :]  # [1, I+W]
    h1, c1 = lstm_cell(x_in, h[None, :], c[None, :], wx, wh, b)
    p = (h1 @ w_head.T + b_head)[0]  # [2W+3]
    q, a = p[:word], p[word : 2 * word]
    alpha = 1.0 / (1.0 + jnp.exp(-p[2 * word]))
    gamma = 1.0 / (1.0 + jnp.exp(-p[2 * word + 1]))
    beta_raw = p[2 * word + 2]

    # Write (eq. 5): least-used row is erased then everything gets the add.
    lra = jnp.argmin(usage)
    w_write = alpha * gamma * w_read_prev
    w_write = w_write.at[lra].add(alpha * (1.0 - gamma))
    mem = mem * (1.0 - jnp.eye(mem.shape[0])[lra])[:, None]  # erase LRA row
    mem = mem + w_write[:, None] * a[None, :]

    # Read via the fused Pallas kernel.
    r = dam_read_cell(q[None, :], beta_raw[None], mem)[0]
    _, w_read_full = ref.content_attention(
        q[None, :], jnp.logaddexp(beta_raw, 0.0)[None] + 1.0, mem
    )
    w_read = w_read_full[0]

    # Usage U⁽¹⁾ update.
    usage = 0.99 * usage + w_write + w_read

    y = jnp.concatenate([h1[0], r]) @ w_out.T + b_out
    return y, h1[0], c1[0], mem, usage, w_read, r


def shapes_for(config):
    """Example-argument shapes per artifact (single source of truth for
    aot.py and the Rust parity tests)."""
    import jax

    f32 = jnp.float32
    i32 = jnp.int32
    b, i, hdim, n, w, k = (
        config["batch"], config["x_dim"], config["hidden"],
        config["mem_words"], config["word"], config["k"],
    )
    sds = jax.ShapeDtypeStruct
    return {
        "lstm_cell": (
            sds((b, i), f32), sds((b, hdim), f32), sds((b, hdim), f32),
            sds((4 * hdim, i), f32), sds((4 * hdim, hdim), f32), sds((4 * hdim,), f32),
        ),
        "dam_read": (sds((b, w), f32), sds((b,), f32), sds((n, w), f32)),
        "sam_read": (sds((n, w), f32), sds((b, k), i32), sds((b, k), f32)),
        "sam_read_softmax": (
            sds((n, w), f32), sds((b, k), i32), sds((b, w), f32), sds((b,), f32),
        ),
        "dam_step": (
            sds((i,), f32), sds((hdim,), f32), sds((hdim,), f32),
            sds((n, w), f32), sds((n,), f32), sds((n,), f32), sds((w,), f32),
            sds((4 * hdim, i + w), f32), sds((4 * hdim, hdim), f32), sds((4 * hdim,), f32),
            sds((2 * w + 3, hdim), f32), sds((2 * w + 3,), f32),
            sds((w, hdim + w), f32), sds((w,), f32),
        ),
    }


DEFAULT_CONFIG = {
    "batch": 1,
    "x_dim": 16,
    "hidden": 32,
    "mem_words": 64,
    "word": 32,
    "k": 4,
}

CELLS = {
    "lstm_cell": lstm_cell,
    "dam_read": dam_read_cell,
    "sam_read": sam_read_cell,
    "sam_read_softmax": sam_read_softmax_cell,
    "dam_step": dam_step_cell,
}
