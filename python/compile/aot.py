"""AOT lowering: jax → HLO *text* artifacts for the Rust PJRT runtime.

Run once by ``make artifacts``; Python never touches the request path.

The interchange format is HLO text, NOT a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links) rejects with
``proto.id() <= INT_MAX``. The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and DESIGN.md).

Usage:  python -m compile.aot --out-dir ../artifacts [--hidden 32 ...]
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side can uniformly ``to_tuple()`` results)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: str, config: dict) -> dict:
    """Lower every cell in model.CELLS; returns {name: path}."""
    os.makedirs(out_dir, exist_ok=True)
    shapes = model.shapes_for(config)
    written = {}
    for name, fn in model.CELLS.items():
        args = shapes[name]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written[name] = path
        print(f"  {name}: {len(text)} chars -> {path}")
    # Record the shapes the artifacts were lowered for (the Rust parity
    # tests read this instead of hard-coding dims).
    meta = {"config": config}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return written


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    for key, dflt in model.DEFAULT_CONFIG.items():
        ap.add_argument(f"--{key.replace('_', '-')}", type=int, default=dflt)
    ns = ap.parse_args()
    config = {k: getattr(ns, k) for k in model.DEFAULT_CONFIG}
    print(f"lowering cells with config {config}")
    build_all(ns.out_dir, config)
    return 0


if __name__ == "__main__":
    sys.exit(main())
