"""L1 Pallas kernel: dense content-based addressing (paper eq. 1-2).

This is the dense models' per-step hot spot — the O(N·W) cosine-similarity
softmax read that SAM's ANN index replaces with an O(log N) lookup. On the
dense path it dominates the roofline, so it is the kernel worth fusing.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targeted a
CPU (Torch7+Eigen); we re-think the operation for TPU idiom instead of
porting loops. The memory is tiled along N into MXU-aligned blocks that
stream HBM→VMEM; each grid step computes one q·Mᵀ block on the MXU and
folds it into an *online softmax* (running max / denominator / weighted
sum, flash-attention style), so the full N-sized attention row never
materializes in HBM and VMEM holds only [BLOCK_N, W] + small accumulators.
The accumulators are grid-persistent outputs pinned to block (0,0) — the
standard Pallas accumulator idiom.

Grid:    (N // BLOCK_N,)
VMEM:    q [B,W], beta [B], mem block [BLOCK_N,W], read/acc [B,W], m/z [B]
Per step: one [B,W]×[W,BLOCK_N] MXU matmul + VPU online-softmax update.

interpret=True everywhere: the CPU image cannot execute Mosaic custom
calls; real-TPU performance is estimated analytically in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_N = 128  # lane-aligned for the MXU/VPU


def _kernel(q_ref, beta_ref, mem_ref, read_ref, m_ref, z_ref, acc_ref, *, floor):
    """One grid step: fold memory block j into the online softmax."""
    j = pl.program_id(0)
    q = q_ref[...]          # [B, W]
    mem = mem_ref[...]      # [BLOCK_N, W]
    beta = beta_ref[...]    # [B]

    # Norm-floored cosine similarities for this block: [B, BLOCK_N].
    nq = jnp.maximum(jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True)), floor)
    nm = jnp.maximum(jnp.sqrt(jnp.sum(mem * mem, axis=-1)), floor)
    sims = (q @ mem.T) / (nq * nm[None, :])
    logits = beta[:, None] * sims

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, -jnp.inf, m_ref.dtype)
        z_ref[...] = jnp.zeros(z_ref.shape, z_ref.dtype)
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    # Online-softmax recurrence (flash-attention style).
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    scale = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    z_ref[...] = z_ref[...] * scale + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * scale[:, None] + p @ mem
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(0) - 1)
    def _finish():
        read_ref[...] = acc_ref[...] / z_ref[...][:, None]


def content_attention(q, beta, mem, block_n=DEFAULT_BLOCK_N):
    """Fused content-addressed read: returns the read word [B, W].

    Matches ``ref.content_attention(q, beta, mem)[0]`` to f32 tolerance.
    q: [B, W], beta: [B] (β ≥ 1 post-activation), mem: [N, W].

    Differentiable: the Pallas kernel computes the forward; the VJP is the
    closed-form gradient of the reference attention (the usual pattern for
    hand-written kernels — backward runs the math, not the kernel).
    """
    return _content_attention_vjp(q, beta, mem, min(block_n, mem.shape[0]))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _content_attention_vjp(q, beta, mem, block_n):
    return _content_attention_fwd_kernel(q, beta, mem, block_n)


def _content_attention_fwd(q, beta, mem, block_n):
    return _content_attention_fwd_kernel(q, beta, mem, block_n), (q, beta, mem)


def _content_attention_bwd(block_n, res, d_read):
    q, beta, mem = res
    _, vjp = jax.vjp(lambda q, b, m: ref.content_attention(q, b, m)[0], q, beta, mem)
    return vjp(d_read)


_content_attention_vjp.defvjp(_content_attention_fwd, _content_attention_bwd)


def _content_attention_fwd_kernel(q, beta, mem, block_n):
    b, w = q.shape
    n, _ = mem.shape
    assert n % block_n == 0, f"N={n} must be a multiple of block_n={block_n}"
    outs = pl.pallas_call(
        functools.partial(_kernel, floor=ref.NORM_FLOOR),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((b, w), lambda j: (0, 0)),        # q: VMEM-resident
            pl.BlockSpec((b,), lambda j: (0,)),            # beta
            pl.BlockSpec((block_n, w), lambda j: (j, 0)),  # memory streams
        ],
        out_specs=[
            pl.BlockSpec((b, w), lambda j: (0, 0)),  # read
            pl.BlockSpec((b,), lambda j: (0,)),      # running max
            pl.BlockSpec((b,), lambda j: (0,)),      # running denom
            pl.BlockSpec((b, w), lambda j: (0, 0)),  # running weighted sum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, w), q.dtype),
            jax.ShapeDtypeStruct((b,), q.dtype),
            jax.ShapeDtypeStruct((b,), q.dtype),
            jax.ShapeDtypeStruct((b, w), q.dtype),
        ],
        interpret=True,
    )(q, beta, mem)
    return outs[0]


def vmem_footprint_bytes(b, w, block_n=DEFAULT_BLOCK_N, dtype_bytes=4):
    """Analytic VMEM footprint of one grid step (for the §Perf estimates):
    q + beta + memory block + 4 accumulators."""
    return dtype_bytes * (b * w + b + block_n * w + 2 * (b * w) + 2 * b)


def mxu_flops_per_step(b, w, block_n=DEFAULT_BLOCK_N):
    """MXU matmul FLOPs per grid step: sims (B×W×BLOCK_N) + p@mem."""
    return 2 * b * w * block_n * 2
