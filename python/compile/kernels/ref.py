"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

These mirror the paper's math exactly and the Rust implementation's
numerics (same norm-floored cosine as `rust/src/cores/addressing.rs`), so
the same reference validates (a) the Pallas kernels at build time via
pytest and (b) the Rust cores via the HLO parity tests.
"""

import jax.numpy as jnp

# Must match addressing::NORM_FLOOR on the rust side.
NORM_FLOOR = 0.1


def cosine_sims(q, mem):
    """Norm-floored cosine similarity of queries against all memory rows.

    q:   [B, W] queries
    mem: [N, W] memory
    returns [B, N]
    """
    nq = jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), NORM_FLOOR)  # [B,1]
    nm = jnp.maximum(jnp.linalg.norm(mem, axis=-1, keepdims=True), NORM_FLOOR)  # [N,1]
    return (q @ mem.T) / (nq * nm.T)


def content_attention(q, beta, mem):
    """Dense content-based read (paper eq. 1-2): softmax(β·cos) weights and
    the weighted read word.

    q:    [B, W], beta: [B] (post-activation, β ≥ 1), mem: [N, W]
    returns (read [B, W], weights [B, N])
    """
    sims = cosine_sims(q, mem)  # [B, N]
    logits = beta[:, None] * sims
    weights = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    read = weights @ mem
    return read, weights


def sparse_read(mem, idx, weights):
    """K-sparse read (paper eq. 4): r = Σ_k w(k) · M[s_k].

    mem: [N, W], idx: [B, K] int32, weights: [B, K]
    returns [B, W]
    """
    rows = mem[idx]  # [B, K, W]
    return jnp.einsum("bk,bkw->bw", weights, rows)


def lstm_cell(x, h, c, wx, wh, b, forget_bias=1.0):
    """Standard LSTM cell, gate order [i, f, g, o] (matches rust nn::lstm).

    x: [B, I], h/c: [B, H], wx: [4H, I], wh: [4H, H], b: [4H]
    returns (h', c')
    """
    hs = h.shape[-1]
    z = x @ wx.T + h @ wh.T + b
    sig = lambda t: 1.0 / (1.0 + jnp.exp(-t))
    i = sig(z[:, :hs])
    f = sig(z[:, hs : 2 * hs] + forget_bias)
    g = jnp.tanh(z[:, 2 * hs : 3 * hs])
    o = sig(z[:, 3 * hs :])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new
