"""L1 Pallas kernel: K-sparse gather read (paper eq. 4) and its scatter-add
write dual (the sparse half of eq. 3).

These are SAM's per-step memory touches: r̃ = Σ_k w̃(s_k)·M(s_k) and
M(s_k) += w^W(s_k)·a. K is a small constant (paper: 4-8), so the kernels
are gather/scatter-bound, not compute-bound; the Pallas expression keeps
the K rows in VMEM and uses dynamic-slice loads indexed from SMEM-style
scalar refs, which is exactly how a TPU would avoid streaming the whole
memory for a K-row touch.

Indices are passed as int32 tensors. interpret=True (see package docs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _read_kernel(idx_ref, w_ref, mem_ref, out_ref, *, k):
    """out[b,:] = Σ_k w[b,k] · mem[idx[b,k],:] — K dynamic-slice row loads."""
    b = out_ref.shape[0]
    acc = jnp.zeros(out_ref.shape, out_ref.dtype)
    for bi in range(b):  # B and K are small static constants: unrolled
        row_acc = jnp.zeros((out_ref.shape[1],), out_ref.dtype)
        for ki in range(k):
            row = pl.load(mem_ref, (pl.dslice(idx_ref[bi, ki], 1), slice(None)))
            row_acc = row_acc + w_ref[bi, ki] * row[0]
        acc = acc.at[bi].set(row_acc)
    out_ref[...] = acc


def sparse_read(mem, idx, weights):
    """K-sparse read. mem: [N,W] f32, idx: [B,K] i32, weights: [B,K] f32.
    Returns [B, W]. Matches ``ref.sparse_read``.

    Differentiable in (mem, weights) via a closed-form VJP — the sparse
    gradients of Supp A.2: dL/dw̃(k) = M(s_k)·dL/dr̃ and dL/dM(s_k) =
    w̃(k)·dL/dr̃ (zero elsewhere)."""
    return _sparse_read_vjp(mem, idx, weights)


@jax.custom_vjp
def _sparse_read_vjp(mem, idx, weights):
    return _sparse_read_kernel(mem, idx, weights)


def _sparse_read_fwd(mem, idx, weights):
    return _sparse_read_kernel(mem, idx, weights), (mem.shape, idx, weights, mem)


def _sparse_read_bwd(res, d_r):
    mem_shape, idx, weights, mem = res
    rows = mem[idx]  # [B,K,W]
    d_w = jnp.einsum("bw,bkw->bk", d_r, rows)
    d_mem = jnp.zeros(mem_shape, d_r.dtype)
    # scatter-add w(k)·dr into the touched rows
    updates = weights[:, :, None] * d_r[:, None, :]  # [B,K,W]
    d_mem = d_mem.at[idx].add(updates)
    return d_mem, None, d_w


_sparse_read_vjp.defvjp(_sparse_read_fwd, _sparse_read_bwd)


def _sparse_read_kernel(mem, idx, weights):
    b, k = idx.shape
    n, w = mem.shape
    return pl.pallas_call(
        functools.partial(_read_kernel, k=k),
        # Whole-array specs: the kernel dynamic-slices the K rows it needs;
        # on real hardware M stays in HBM/ANY and only K rows hit VMEM.
        in_specs=[
            pl.BlockSpec((b, k), lambda: (0, 0)),
            pl.BlockSpec((b, k), lambda: (0, 0)),
            pl.BlockSpec((n, w), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, w), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, w), mem.dtype),
        interpret=True,
    )(idx, weights, mem)


def _write_kernel(idx_ref, w_ref, word_ref, mem_ref, out_ref, *, k):
    """Scatter-add: out = mem; out[idx[k],:] += w[k]·word  (single batch)."""
    out_ref[...] = mem_ref[...]
    for ki in range(k):
        i = idx_ref[ki]
        row = pl.load(out_ref, (pl.dslice(i, 1), slice(None)))
        pl.store(
            out_ref,
            (pl.dslice(i, 1), slice(None)),
            row + w_ref[ki] * word_ref[...][None, :],
        )


def sparse_write(mem, idx, weights, word):
    """Sparse additive write (the add half of eq. 3).
    mem: [N,W], idx: [K] i32, weights: [K], word: [W] → new [N,W]."""
    n, w = mem.shape
    (k,) = idx.shape
    return pl.pallas_call(
        functools.partial(_write_kernel, k=k),
        in_specs=[
            pl.BlockSpec((k,), lambda: (0,)),
            pl.BlockSpec((k,), lambda: (0,)),
            pl.BlockSpec((w,), lambda: (0,)),
            pl.BlockSpec((n, w), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, w), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, w), mem.dtype),
        interpret=True,
    )(idx, weights, word, mem)
