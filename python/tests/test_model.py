"""L2 model cells: shapes, semantics, and internal consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape).astype(jnp.float32)


def test_lstm_cell_gates_behave():
    b, i, h = 2, 5, 7
    x = rand(0, (b, i))
    h0 = jnp.zeros((b, h))
    c0 = jnp.zeros((b, h))
    wx = rand(1, (4 * h, i), 0.3)
    wh = rand(2, (4 * h, h), 0.3)
    bias = jnp.zeros(4 * h)
    h1, c1 = model.lstm_cell(x, h0, c0, wx, wh, bias)
    assert h1.shape == (b, h) and c1.shape == (b, h)
    # h is bounded by tanh x sigmoid
    assert np.abs(np.array(h1)).max() <= 1.0
    # zero input & state with zero weights -> zero-ish state
    h2, c2 = model.lstm_cell(jnp.zeros((b, i)), h0, c0, jnp.zeros_like(wx), jnp.zeros_like(wh), bias)
    np.testing.assert_allclose(np.array(h2), 0.0, atol=1e-6)


def test_dam_read_cell_matches_ref_attention():
    q = rand(3, (1, 32))
    mem = rand(4, (128, 32))
    beta_raw = jnp.array([0.5])
    out = model.dam_read_cell(q, beta_raw, mem)
    beta = jnp.logaddexp(beta_raw, 0.0) + 1.0
    want, _ = ref.content_attention(q, beta, mem)
    np.testing.assert_allclose(np.array(out), np.array(want), atol=2e-5, rtol=1e-4)


def test_sam_read_softmax_cell_weights_normalized():
    mem = rand(5, (64, 16))
    idx = jnp.array([[3, 17, 42, 60]], dtype=jnp.int32)
    q = rand(6, (1, 16))
    read, w = model.sam_read_softmax_cell(mem, idx, q, jnp.array([0.0]))
    np.testing.assert_allclose(np.array(w.sum(axis=-1)), 1.0, atol=1e-5)
    # read is inside the convex hull scale of gathered rows
    rows = np.array(mem)[np.array(idx[0])]
    assert np.abs(np.array(read)).max() <= np.abs(rows).max() + 1e-5


def test_sam_read_softmax_matches_dense_restricted():
    # Restricting dense attention to the K rows must equal the sparse cell.
    mem = rand(7, (32, 8))
    idx = jnp.array([[1, 9, 20]], dtype=jnp.int32)
    q = rand(8, (1, 8))
    braw = jnp.array([0.3])
    read, w = model.sam_read_softmax_cell(mem, idx, q, braw)
    sub = mem[idx[0]]
    beta = jnp.logaddexp(braw, 0.0) + 1.0
    want, wref = ref.content_attention(q, beta, sub)
    np.testing.assert_allclose(np.array(read), np.array(want), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.array(w), np.array(wref), atol=2e-5, rtol=1e-4)


def test_dam_step_cell_full_semantics():
    cfg = model.DEFAULT_CONFIG
    i, h, n, w = cfg["x_dim"], cfg["hidden"], cfg["mem_words"], cfg["word"]
    x = rand(10, (i,))
    h0 = jnp.zeros(h)
    c0 = jnp.zeros(h)
    mem = rand(11, (n, w), 0.1)
    usage = jnp.zeros(n)
    w_read_prev = jnp.zeros(n)
    r_prev = jnp.zeros(w)
    wx = rand(12, (4 * h, i + w), 0.2)
    wh = rand(13, (4 * h, h), 0.2)
    b = jnp.zeros(4 * h)
    w_head = rand(14, (2 * w + 3, h), 0.2)
    b_head = jnp.zeros(2 * w + 3)
    w_out = rand(15, (w, h + w), 0.2)
    b_out = jnp.zeros(w)
    y, h1, c1, mem1, usage1, w_read, r = model.dam_step_cell(
        x, h0, c0, mem, usage, w_read_prev, r_prev,
        wx, wh, b, w_head, b_head, w_out, b_out,
    )
    assert y.shape == (w,)
    assert mem1.shape == (n, w)
    # read weights are a distribution
    np.testing.assert_allclose(float(w_read.sum()), 1.0, atol=1e-4)
    assert float(usage1.sum()) > 0.0
    # repeated application keeps everything finite (5 steps)
    state = (h1, c1, mem1, usage1, w_read, r)
    for _ in range(5):
        y, *state = model.dam_step_cell(
            x, *state[:2], *state[2:], wx, wh, b, w_head, b_head, w_out, b_out
        )
        state = tuple(state)
    assert np.isfinite(np.array(y)).all()


def test_shapes_for_covers_all_cells():
    shapes = model.shapes_for(model.DEFAULT_CONFIG)
    assert set(shapes) == set(model.CELLS)
    # every cell traces with its declared shapes
    for name, fn in model.CELLS.items():
        jax.eval_shape(fn, *shapes[name])


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
