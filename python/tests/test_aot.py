"""AOT lowering: every cell lowers to parseable HLO text with the right
entry signature, and the manifest records the config."""

import json
import os
import tempfile

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts():
    with tempfile.TemporaryDirectory() as d:
        cfg = dict(model.DEFAULT_CONFIG)
        cfg.update({"mem_words": 32, "hidden": 16, "x_dim": 8, "word": 16})
        written = aot.build_all(d, cfg)
        yield d, cfg, written


def test_all_cells_lowered(artifacts):
    d, _, written = artifacts
    assert set(written) == set(model.CELLS)
    for path in written.values():
        assert os.path.getsize(path) > 100


def test_hlo_text_shape(artifacts):
    d, cfg, written = artifacts
    text = open(written["lstm_cell"]).read()
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    # 6 parameters for the lstm cell
    assert text.count("parameter(") == 6
    # lowered for the configured hidden size
    assert f"f32[{4 * cfg['hidden']}," in text


def test_manifest_written(artifacts):
    d, cfg, _ = artifacts
    meta = json.load(open(os.path.join(d, "manifest.json")))
    assert meta["config"] == cfg


def test_pallas_kernel_lowers_to_plain_hlo(artifacts):
    # interpret=True must leave no custom-call in the lowered module,
    # otherwise the Rust CPU PJRT client can't execute it.
    d, _, written = artifacts
    for name in ("dam_read", "sam_read"):
        text = open(written[name]).read()
        assert "custom-call" not in text, f"{name} contains a custom call"


def test_repo_artifacts_match_repo_manifest():
    # If `make artifacts` has run, the checked manifest matches DEFAULT_CONFIG.
    repo_manifest = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
    )
    if not os.path.exists(repo_manifest):
        pytest.skip("artifacts not built")
    meta = json.load(open(repo_manifest))
    assert set(meta["config"]) == set(model.DEFAULT_CONFIG)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
