"""L1 Pallas kernels vs the pure-jnp oracle, swept with hypothesis over
shapes, dtypes-scales, and seeds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import content_addressing as ca
from compile.kernels import ref
from compile.kernels import sparse_read as sr

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape).astype(jnp.float32)


# ---------------------------------------------------------------------------
# content_addressing (online-softmax attention)
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 4),
    w=st.sampled_from([8, 16, 32]),
    n_blocks=st.integers(1, 6),
    block_n=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([0.01, 1.0, 10.0]),
)
def test_content_attention_matches_ref(b, w, n_blocks, block_n, seed, scale):
    n = n_blocks * block_n
    q = rand(seed, (b, w))
    mem = rand(seed + 1, (n, w), scale)
    beta = jnp.abs(rand(seed + 2, (b,))) + 1.0
    out = ca.content_attention(q, beta, mem, block_n=block_n)
    want, _ = ref.content_attention(q, beta, mem)
    np.testing.assert_allclose(np.array(out), np.array(want), atol=2e-5, rtol=2e-4)


def test_content_attention_zero_memory_is_uniform_read():
    # All-zero memory: similarities tie at 0, weights uniform, read = 0.
    q = rand(0, (1, 16))
    mem = jnp.zeros((64, 16))
    beta = jnp.array([5.0])
    out = ca.content_attention(q, beta, mem, block_n=32)
    np.testing.assert_allclose(np.array(out), np.zeros((1, 16)), atol=1e-6)


def test_content_attention_sharp_beta_picks_nearest():
    mem = rand(3, (128, 16))
    q = mem[37:38] * 2.0  # same direction as row 37
    beta = jnp.array([200.0])  # very sharp softmax
    out = ca.content_attention(q, beta, mem, block_n=32)
    np.testing.assert_allclose(np.array(out[0]), np.array(mem[37]), atol=1e-3, rtol=1e-3)


def test_block_size_invariance():
    q = rand(4, (2, 32))
    mem = rand(5, (256, 32))
    beta = jnp.array([1.0, 3.0])
    outs = [ca.content_attention(q, beta, mem, block_n=bn) for bn in (16, 64, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.array(outs[0]), np.array(o), atol=2e-5)


def test_vmem_and_flop_estimates_positive():
    assert ca.vmem_footprint_bytes(1, 32) > 0
    assert ca.mxu_flops_per_step(1, 32) > 0


# ---------------------------------------------------------------------------
# sparse_read / sparse_write (gather/scatter kernels)
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 3),
    k=st.integers(1, 8),
    n=st.sampled_from([16, 64, 256]),
    w=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
)
def test_sparse_read_matches_ref(b, k, n, w, seed):
    mem = rand(seed, (n, w))
    key = jax.random.PRNGKey(seed + 1)
    idx = jax.random.randint(key, (b, k), 0, n, dtype=jnp.int32)
    weights = rand(seed + 2, (b, k))
    out = sr.sparse_read(mem, idx, weights)
    want = ref.sparse_read(mem, idx, weights)
    np.testing.assert_allclose(np.array(out), np.array(want), atol=1e-5, rtol=1e-5)


def test_sparse_read_duplicate_indices_accumulate():
    mem = jnp.eye(4, dtype=jnp.float32)
    idx = jnp.array([[2, 2, 2]], dtype=jnp.int32)
    w = jnp.array([[0.5, 0.25, 0.25]])
    out = sr.sparse_read(mem, idx, w)
    np.testing.assert_allclose(np.array(out[0]), np.array([0, 0, 1.0, 0]), atol=1e-6)


@given(
    k=st.integers(1, 6),
    n=st.sampled_from([16, 64]),
    w=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_sparse_write_matches_dense_scatter(k, n, w, seed):
    mem = rand(seed, (n, w))
    key = jax.random.PRNGKey(seed + 3)
    idx = jax.random.randint(key, (k,), 0, n, dtype=jnp.int32)
    weights = rand(seed + 4, (k,))
    word = rand(seed + 5, (w,))
    out = sr.sparse_write(mem, idx, weights, word)
    want = np.array(mem)
    for i, ww in zip(np.array(idx), np.array(weights)):
        want[i] += ww * np.array(word)
    np.testing.assert_allclose(np.array(out), want, atol=1e-5, rtol=1e-5)


def test_sparse_write_untouched_rows_bitexact():
    mem = rand(9, (32, 8))
    idx = jnp.array([5], dtype=jnp.int32)
    out = sr.sparse_write(mem, idx, jnp.array([2.0]), jnp.ones(8))
    m0, m1 = np.array(mem), np.array(out)
    mask = np.ones(32, bool)
    mask[5] = False
    np.testing.assert_array_equal(m0[mask], m1[mask])


# ---------------------------------------------------------------------------
# grad flow through the kernels under jax autodiff (interpret mode)
# ---------------------------------------------------------------------------


def test_content_attention_differentiable():
    q = rand(10, (1, 16))
    mem = rand(11, (64, 16))
    beta = jnp.array([2.0])

    def loss(q):
        return ca.content_attention(q, beta, mem, block_n=32).sum()

    g = jax.grad(loss)(q)
    gr = jax.grad(lambda q: ref.content_attention(q, beta, mem)[0].sum())(q)
    np.testing.assert_allclose(np.array(g), np.array(gr), atol=1e-4, rtol=1e-3)


def test_sparse_read_differentiable_in_weights():
    mem = rand(12, (32, 8))
    idx = jnp.array([[1, 5, 9]], dtype=jnp.int32)

    def loss(w):
        return sr.sparse_read(mem, idx, w).sum()

    w0 = jnp.array([[0.2, 0.3, 0.5]])
    g = jax.grad(loss)(w0)
    want = np.array([mem[1].sum(), mem[5].sum(), mem[9].sum()])[None, :]
    np.testing.assert_allclose(np.array(g), want, atol=1e-5, rtol=1e-5)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
