//! Tables 1-2: Babi question answering — per-family error for each model,
//! trained jointly on all families (synthetic Babi-style generator; see
//! DESIGN.md §3 for the substitution).
//!
//! Paper finding (Table 1): MANNs ≪ LSTM/NTM; sparse ≈ dense (SAM ≈ DAM,
//! SDNC ≤ DNC); SDNC best reported. The NTM lags because it cannot
//! allocate memory effectively.
//!
//!     cargo bench --bench table1_babi [-- --paper-scale --updates N]

use sam::bench::{save_results, Table};
use sam::prelude::*;
use sam::tasks::babi::FAMILIES;
use sam::util::json::Json;

fn main() {
    let args = Args::from_env();
    let paper = args.has("paper-scale");
    let updates = args.usize_or("updates", if paper { 20_000 } else { 1500 });
    let story_level = args.usize_or("level", 4);
    let eval_eps = args.usize_or("eval-episodes", if paper { 100 } else { 25 });

    let task = BabiTask::new();
    let models = if paper {
        vec![CoreKind::Lstm, CoreKind::Ntm, CoreKind::Dnc, CoreKind::Sdnc, CoreKind::Dam, CoreKind::Sam]
    } else {
        vec![CoreKind::Lstm, CoreKind::Dam, CoreKind::Sam, CoreKind::Sdnc]
    };

    println!("Table 1 — Babi-style per-family error % after joint training ({updates} updates)\n");
    let mut headers: Vec<String> = vec!["family".into()];
    headers.extend(models.iter().map(|m| format!("{m:?}")));
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);

    // errors[model][family]
    let mut errors = vec![vec![0.0f64; FAMILIES.len()]; models.len()];
    let mut means = vec![0.0f64; models.len()];
    for (mi, kind) in models.iter().enumerate() {
        let cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: if paper { 100 } else { 64 },
            heads: if paper { 4 } else { 2 },
            word: if paper { 32 } else { 16 },
            mem_words: if paper { 2048 } else { 128 },
            k: 4,
            k_l: 8,
            ann: AnnKind::Linear,
            seed: 21,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(21);
        let core = build_core(*kind, &cfg, &mut rng);
        let mut trainer = Trainer::new(
            core,
            Box::new(RmsProp::new(if paper { 1e-4 } else { 3e-3 })),
            TrainConfig {
                batch: if paper { 8 } else { 4 },
                updates,
                log_every: (updates / 10).max(1),
                seed: 21,
                verbose: false,
                ..TrainConfig::default()
            },
        );
        let mut cur = Curriculum::fixed(story_level);
        trainer.run(&task, &mut cur);
        // per-family eval
        for (fi, _) in FAMILIES.iter().enumerate() {
            let fam_task = BabiTask::family(fi);
            let err =
                trainer.evaluate(&fam_task, story_level, eval_eps, 3000 + fi as u64) * 100.0;
            errors[mi][fi] = err;
        }
        means[mi] = errors[mi].iter().sum::<f64>() / FAMILIES.len() as f64;
    }

    for (fi, fam) in FAMILIES.iter().enumerate() {
        let mut row = vec![fam.to_string()];
        for mi in 0..models.len() {
            row.push(format!("{:.1}", errors[mi][fi]));
        }
        table.row(row);
    }
    let mut mean_row = vec!["Mean Error (%)".to_string()];
    let mut failed_row = vec!["Failed (err > 5%)".to_string()];
    for mi in 0..models.len() {
        mean_row.push(format!("{:.1}", means[mi]));
        failed_row.push(errors[mi].iter().filter(|&&e| e > 5.0).count().to_string());
    }
    table.row(mean_row);
    table.row(failed_row);
    table.print();

    let results: Vec<Json> = models
        .iter()
        .enumerate()
        .map(|(mi, kind)| {
            Json::obj(vec![
                ("model", Json::str(format!("{kind:?}"))),
                ("mean_error_pct", Json::num(means[mi])),
                (
                    "per_family",
                    Json::Arr(errors[mi].iter().map(|&e| Json::num(e)).collect()),
                ),
            ])
        })
        .collect();
    println!("\nexpectation: MANNs ≪ LSTM; sparse ≈ dense (paper Table 1: SDNC 2.9%, DAM 3.3%, SAM 4.1%, DNC 5.2%, NTM 17.5%, LSTM 28%)");
    save_results("table1_babi", Json::arr(results));
}
