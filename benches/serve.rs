//! Serving-runtime perf harness: per-step latency percentiles and batched
//! session throughput for the shared-weight inference runtime.
//!
//! Writes `BENCH_serve.json` at the repo root (CI uploads it as an
//! artifact next to BENCH_kernels.json / BENCH_step.json):
//!
//! * p50/p95/p99 single-step latency through `SessionManager::step`;
//! * session-steps/second through the batched `step_many` tick at several
//!   concurrency levels (the coalesced-GEMM payoff);
//! * per-session state bytes vs the single shared parameter copy.
//!
//!     cargo bench --bench serve [-- --smoke] [-- --sessions 64]

use sam::bench::{fmt_bytes, save_bench_root, Table};
use sam::cores::{CoreConfig, CoreKind};
use sam::prelude::*;
use sam::serving::{build_infer_model, InferModel as _, SessionConfig, SessionManager};
use sam::util::json::Json;
use sam::util::metrics;
use sam::util::timer::Timer;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let i = ((sorted.len() - 1) as f64 * p) as usize;
    sorted[i]
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let steps = args.usize_or("steps", if smoke { 64 } else { 512 });
    let mem_words = args.usize_or("memory", if smoke { 1 << 10 } else { 1 << 14 });
    let levels: Vec<usize> = if smoke { vec![1, 8] } else { vec![1, 8, 32, 128] };
    let max_sessions = args.usize_or("sessions", *levels.last().unwrap());

    let cfg = CoreConfig {
        x_dim: 16,
        y_dim: 16,
        hidden: if smoke { 32 } else { 100 },
        heads: 4,
        word: 32,
        mem_words,
        k: 4,
        ann: AnnKind::Linear,
        seed: 21,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(21);
    let model = build_infer_model(CoreKind::Sam, &cfg, &mut rng, None);
    let params_bytes = model.params_heap_bytes();
    let mgr = SessionManager::new(model, SessionConfig::default());

    // ---- single-step latency ---------------------------------------------
    let id = mgr.open_seeded(Some(1));
    let mut xrng = Rng::new(22);
    let mut y = Vec::new();
    // Warm the pools before timing.
    for _ in 0..8 {
        let x: Vec<f32> = (0..cfg.x_dim).map(|_| xrng.normal()).collect();
        mgr.step(id, &x, &mut y).unwrap();
    }
    let mut lat = Vec::with_capacity(steps);
    for _ in 0..steps {
        let x: Vec<f32> = (0..cfg.x_dim).map(|_| xrng.normal()).collect();
        let t = Timer::start();
        mgr.step(id, &x, &mut y).unwrap();
        lat.push(t.elapsed_s());
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p95, p99) = (
        percentile(&lat, 0.5) * 1e6,
        percentile(&lat, 0.95) * 1e6,
        percentile(&lat, 0.99) * 1e6,
    );
    println!(
        "single-step latency (N={mem_words}): p50 {p50:.1} µs  p95 {p95:.1} µs  p99 {p99:.1} µs"
    );

    // ---- batched throughput at several concurrency levels ----------------
    let mut table = Table::new(&["sessions", "ticks", "steps/s", "state/session"]);
    let mut level_rows = Vec::new();
    let mut pool_ids: Vec<u64> = Vec::new();
    for &n in levels.iter().filter(|&&n| n <= max_sessions) {
        while pool_ids.len() < n {
            pool_ids.push(mgr.open_seeded(Some(100 + pool_ids.len() as u64)));
        }
        let ids = &pool_ids[..n];
        let ticks = (steps / n).max(4);
        let mut outs = Vec::new();
        // Warm tick.
        let reqs: Vec<(u64, Vec<f32>)> = ids
            .iter()
            .map(|&id| (id, (0..cfg.x_dim).map(|_| xrng.normal()).collect()))
            .collect();
        mgr.step_many(&reqs, &mut outs);
        let t = Timer::start();
        for _ in 0..ticks {
            let reqs: Vec<(u64, Vec<f32>)> = ids
                .iter()
                .map(|&id| (id, (0..cfg.x_dim).map(|_| xrng.normal()).collect()))
                .collect();
            mgr.step_many(&reqs, &mut outs);
            for o in &outs {
                assert!(o.is_ok(), "bench step failed: {o:?}");
            }
        }
        let el = t.elapsed_s();
        let steps_per_s = (ticks * n) as f64 / el;
        let per_session = mgr.state_heap_bytes() / mgr.session_count();
        table.row(vec![
            n.to_string(),
            ticks.to_string(),
            format!("{steps_per_s:.0}"),
            fmt_bytes(per_session),
        ]);
        level_rows.push(Json::obj(vec![
            ("sessions", Json::num(n as f64)),
            ("ticks", Json::num(ticks as f64)),
            ("steps_per_s", Json::num(steps_per_s)),
            ("state_bytes_per_session", Json::num(per_session as f64)),
        ]));
    }
    table.print();
    println!(
        "one shared weight copy: {} · sessions resident: {}",
        fmt_bytes(params_bytes),
        mgr.session_count()
    );

    // ---- durability: spill / rehydrate latency ---------------------------
    // Demote one warmed session to disk and load it back, round-robin over
    // a handful of iterations — the cost a served client pays for the
    // transparent rehydrate-on-next-step path.
    let spill_dir = std::env::temp_dir().join(format!("sam-bench-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_dir);
    std::fs::create_dir_all(&spill_dir).unwrap();
    let durable = SessionManager::new(
        {
            let mut rng = Rng::new(21);
            build_infer_model(CoreKind::Sam, &cfg, &mut rng, None)
        },
        SessionConfig {
            idle_expiry: std::time::Duration::from_millis(0),
            spill_dir: Some(spill_dir.clone()),
            ..SessionConfig::default()
        },
    );
    let sid = durable.open_seeded(Some(9));
    for _ in 0..8 {
        let x: Vec<f32> = (0..cfg.x_dim).map(|_| xrng.normal()).collect();
        durable.step(sid, &x, &mut y).unwrap();
    }
    let spill_iters = if smoke { 4 } else { 16 };
    let (mut spill_s, mut rehydrate_s) = (0.0, 0.0);
    for _ in 0..spill_iters {
        std::thread::sleep(std::time::Duration::from_millis(1));
        let t = Timer::start();
        assert_eq!(durable.expire_idle(), 1, "bench session failed to spill");
        spill_s += t.elapsed_s();
        let x: Vec<f32> = (0..cfg.x_dim).map(|_| xrng.normal()).collect();
        let t = Timer::start();
        durable.step(sid, &x, &mut y).unwrap(); // rehydrates + one step
        rehydrate_s += t.elapsed_s();
    }
    let spill_bytes = std::fs::metadata(sam::serving::spill::spill_path(&spill_dir, sid))
        .map(|m| m.len())
        .unwrap_or_else(|_| {
            // The file was consumed by the last rehydrate; spill once more
            // just to measure its size.
            std::thread::sleep(std::time::Duration::from_millis(1));
            durable.expire_idle();
            std::fs::metadata(sam::serving::spill::spill_path(&spill_dir, sid))
                .map(|m| m.len())
                .unwrap_or(0)
        });
    let spill_us = spill_s / spill_iters as f64 * 1e6;
    let rehydrate_us = rehydrate_s / spill_iters as f64 * 1e6;
    println!(
        "spill/rehydrate (N={mem_words}): spill {spill_us:.1} µs  rehydrate+step {rehydrate_us:.1} µs  file {}",
        fmt_bytes(spill_bytes as usize)
    );
    let _ = std::fs::remove_dir_all(&spill_dir);

    save_bench_root(
        "serve",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("mem_words", Json::num(mem_words as f64)),
            ("steps", Json::num(steps as f64)),
            ("p50_us", Json::num(p50)),
            ("p95_us", Json::num(p95)),
            ("p99_us", Json::num(p99)),
            ("params_bytes", Json::num(params_bytes as f64)),
            ("levels", Json::Arr(level_rows)),
            (
                "spill",
                Json::obj(vec![
                    ("iters", Json::num(spill_iters as f64)),
                    ("spill_us", Json::num(spill_us)),
                    ("rehydrate_step_us", Json::num(rehydrate_us)),
                    ("file_bytes", Json::num(spill_bytes as f64)),
                ]),
            ),
            // Registry view of the same run: the step-latency histogram the
            // `{"metrics"}` endpoint would report (bucketed, so the
            // percentiles are upper bounds vs the exact ones above).
            (
                "metrics",
                Json::obj(vec![
                    (
                        "step_latency_us",
                        metrics::hist_summary_json(&metrics::SERVE_STEP_LATENCY_US),
                    ),
                    (
                        "queue_latency_us",
                        metrics::hist_summary_json(&metrics::SERVE_QUEUE_LATENCY_US),
                    ),
                ]),
            ),
        ]),
    );
}
