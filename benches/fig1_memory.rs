//! Figure 1b: physical memory used to train over a 100-step sequence vs
//! memory size N, excluding initialization of the external memory —
//! measured with the counting global allocator ([`sam::util::alloc`]),
//! exactly the paper's quantity.
//!
//! Paper headline: at N = 64K words the NTM consumes 29 GiB while SAM
//! consumes 7.8 MiB (~3700×); SAM's line is flat in N.
//!
//!     cargo bench --bench fig1_memory [-- --paper-scale --steps 100]

use sam::bench::{fmt_bytes, save_results, Table};
use sam::prelude::*;
use sam::util::alloc::MemRegion;
use sam::util::json::Json;

/// Peak extra heap for a T-step fwd+bwd episode, after init.
fn episode_peak(kind: CoreKind, n: usize, t_steps: usize) -> (usize, usize) {
    let cfg = CoreConfig {
        x_dim: 8,
        y_dim: 8,
        hidden: 100,
        heads: 4,
        word: 32,
        mem_words: n,
        k: 4,
        ann: AnnKind::Linear,
        seed: 2,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(2);
    let mut core = build_core(kind, &cfg, &mut rng);
    core.reset();
    let x = vec![0.5f32; 8];
    let dy = vec![0.1f32; 8];
    // Warm one short episode so lazily-grown buffers don't count as
    // sequence cost (mirrors "excluding initialization").
    core.forward(&x);
    core.backward(&dy);
    core.end_episode();
    let region = MemRegion::start();
    core.reset();
    for _ in 0..t_steps {
        core.forward(&x);
    }
    let peak_fwd = region.peak_overhead();
    for _ in 0..t_steps {
        core.backward(&dy);
    }
    core.end_episode();
    (region.peak_overhead(), peak_fwd)
}

/// Guard for the Fig 1b numbers: check the engine's per-part heap reports
/// against *independently computed* expectations (sizes derived here from
/// N and W, not from the engine's own accessors), so a refactor that adds
/// or resizes engine state without accounting for it trips before any
/// figure is emitted.
fn assert_engine_accounting() {
    let (n, word, t_steps) = (256usize, 32usize, 8usize);
    let cfg = CoreConfig {
        x_dim: 8,
        y_dim: 8,
        hidden: 32,
        heads: 4,
        word,
        mem_words: n,
        k: 4,
        ann: AnnKind::Linear,
        seed: 7,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(7);
    let mut core = sam::cores::sam::SamCore::new(&cfg, &mut rng);
    core.reset();
    let x = vec![0.5f32; 8];
    for _ in 0..t_steps {
        core.forward(&x);
    }
    let e = core.engine();
    // Ground truths: the store is exactly N·W f32s; the ring exactly two
    // usize arrays of N; the Linear ANN holds at least its own N·W
    // normalized copy of the rows.
    assert_eq!(e.store_heap_bytes(), n * word * 4, "store accounting drifted");
    assert_eq!(
        e.ring_heap_bytes(),
        2 * n * std::mem::size_of::<usize>(),
        "ring accounting drifted"
    );
    assert!(e.ann_heap_bytes() >= n * word * 4, "ANN must account its row copies");
    // The journal tape must carry one journal per head-step while the
    // episode is live: ≥K distinct rows once reads are warm (steps ≥ 2),
    // ≥1 row (the LRA erase) on the first step where w̃^R is still empty.
    let min_journal = cfg.heads * ((t_steps - 1) * cfg.k + 1) * word * 4;
    assert!(
        e.journal_heap_bytes() >= min_journal,
        "live tape accounts {} B, expected >= {min_journal} B",
        e.journal_heap_bytes()
    );
    // ...and the total must be the sum of the declared parts.
    assert_eq!(
        e.heap_bytes(),
        e.store_heap_bytes()
            + e.ann_heap_bytes()
            + e.ring_heap_bytes()
            + e.journal_heap_bytes()
            + e.grad_heap_bytes()
    );
    core.rollback();
    core.end_episode();
    assert_eq!(
        core.engine().journal_heap_bytes(),
        0,
        "rollback must drain the journal tape"
    );
}

/// Sharded twin of [`assert_engine_accounting`]: heap identities must hold
/// across shard counts with independently computed ground truths, the
/// striped stores must sum to exactly the unsharded store, only the global
/// ring may exist, and a sharded episode's tape must stay within a small
/// constant factor of the unsharded tape (same saved rows + S-1 extra
/// empty journal shells per write) — Fig 1b's flat line survives sharding.
fn assert_sharded_accounting() {
    let (n, word, t_steps) = (256usize, 32usize, 8usize);
    let mut tapes = Vec::new();
    for shards in [1usize, 4] {
        let cfg = CoreConfig {
            x_dim: 8,
            y_dim: 8,
            hidden: 32,
            heads: 4,
            word,
            mem_words: n,
            k: 4,
            ann: AnnKind::Linear,
            shards,
            seed: 7,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(7);
        let mut core = sam::cores::sam::SamCore::new(&cfg, &mut rng);
        core.reset();
        let x = vec![0.5f32; 8];
        for _ in 0..t_steps {
            core.forward(&x);
        }
        let e = core.engine();
        assert_eq!(e.store_heap_bytes(), n * word * 4, "striped stores must sum to N*W (S={shards})");
        assert_eq!(
            e.ring_heap_bytes(),
            2 * n * std::mem::size_of::<usize>(),
            "exactly one (global) ring (S={shards})"
        );
        assert!(e.ann_heap_bytes() >= n * word * 4, "shard ANNs must account row copies");
        assert_eq!(
            e.heap_bytes(),
            e.store_heap_bytes()
                + e.ann_heap_bytes()
                + e.ring_heap_bytes()
                + e.journal_heap_bytes()
                + e.grad_heap_bytes(),
            "sharded heap must be the sum of its parts (S={shards})"
        );
        tapes.push(e.tape_bytes());
        core.rollback();
        core.end_episode();
        assert_eq!(core.engine().tape_bytes(), 0, "sharded rollback must drain every shard tape");
    }
    // Same journaled rows either way; the sharded tape adds only empty
    // per-shard journal shells (bounded, N-independent).
    assert!(
        tapes[1] >= tapes[0] && tapes[1] <= tapes[0] * 2,
        "sharded tape {} vs unsharded {} out of expected envelope",
        tapes[1],
        tapes[0]
    );
}

fn main() {
    assert_engine_accounting();
    assert_sharded_accounting();
    let args = Args::from_env();
    // CI leg: just the accounting identities above (cheap, seconds),
    // without the Fig 1b measurement sweep.
    if args.has("accounting-only") {
        println!("engine + sharded heap-accounting identities OK");
        return;
    }
    let paper = args.has("paper-scale");
    let t_steps = args.usize_or("steps", if paper { 100 } else { 50 });

    let dense_max = if paper { 1 << 16 } else { 1 << 12 };
    let sparse_max = if paper { 1 << 21 } else { 1 << 16 };
    let models: Vec<(&str, CoreKind, usize)> = vec![
        ("NTM", CoreKind::Ntm, dense_max),
        ("DAM", CoreKind::Dam, dense_max),
        ("SAM", CoreKind::Sam, sparse_max),
    ];

    println!("Figure 1b — training memory over a {t_steps}-step sequence vs N (excl. init)\n");
    let mut table = Table::new(&["model", "N", "peak bytes", "pretty"]);
    let mut results = Vec::new();
    let mut ntm_at: std::collections::HashMap<usize, usize> = Default::default();
    let mut ns = Vec::new();
    let mut n = 64;
    while n <= sparse_max {
        ns.push(n);
        n *= 4;
    }
    for (label, kind, max_n) in &models {
        for &n in ns.iter().filter(|&&n| n <= *max_n) {
            let (peak, _fwd) = episode_peak(*kind, n, t_steps);
            if *label == "NTM" {
                ntm_at.insert(n, peak);
            }
            table.row(vec![
                label.to_string(),
                n.to_string(),
                peak.to_string(),
                fmt_bytes(peak),
            ]);
            results.push(Json::obj(vec![
                ("model", Json::str(*label)),
                ("n", Json::num(n as f64)),
                ("peak_bytes", Json::num(peak as f64)),
            ]));
        }
    }
    table.print();

    // Headline compression ratio at the largest dense N.
    let n_big = *ns.iter().filter(|&&n| n <= dense_max).max().unwrap();
    let (sam_big, _) = episode_peak(CoreKind::Sam, n_big, t_steps);
    if let Some(&ntm_big) = ntm_at.get(&n_big) {
        println!(
            "\nheadline @ N={n_big}: NTM {} vs SAM {} -> {:.0}x compression (paper @64K/100 steps: ~3700x)",
            fmt_bytes(ntm_big),
            fmt_bytes(sam_big),
            ntm_big as f64 / sam_big.max(1) as f64
        );
    }
    // Flatness check for SAM (the paper's flat line).
    let (sam_small, _) = episode_peak(CoreKind::Sam, ns[0], t_steps);
    let (sam_large, _) = episode_peak(CoreKind::Sam, sparse_max, t_steps);
    println!(
        "SAM flatness: {} @N={} vs {} @N={} (ratio {:.2} — paper: flat)",
        fmt_bytes(sam_small),
        ns[0],
        fmt_bytes(sam_large),
        sparse_max,
        sam_large as f64 / sam_small.max(1) as f64
    );
    save_results("fig1_memory", Json::arr(results));
}
