//! Figure 7 (Supp D.2): DNC vs SDNC speed and memory at small-to-medium N.
//!
//! Paper headline: at N = 2048 (word 32, 4 heads, T = 10, SDNC with linear
//! KNN) the SDNC is ~440× faster and uses ~240× less memory — the dense
//! DNC's O(N²) temporal linkage dominates. Unlike Fig 1b this plots TOTAL
//! memory (including initialization), since the two models' start states
//! differ (dense L vs sparse N/P matrices).
//!
//!     cargo bench --bench fig7_sdnc [-- --paper-scale]

use sam::bench::{fmt_bytes, fmt_time, measure, save_results, Table};
use sam::prelude::*;
use sam::util::alloc::MemRegion;
use sam::util::json::Json;

fn config(n: usize) -> CoreConfig {
    CoreConfig {
        x_dim: 8,
        y_dim: 8,
        hidden: 100,
        heads: 4,
        word: 32,
        mem_words: n,
        k: 4,
        k_l: 8,
        ann: AnnKind::Linear, // paper: SDNC benchmarked with a linear KNN
        seed: 3,
        ..CoreConfig::default()
    }
}

fn main() {
    let args = Args::from_env();
    let paper = args.has("paper-scale");
    let t_steps = args.usize_or("steps", 10);
    let max_n = if paper { 4096 } else { 2048 };

    let mut ns = vec![64, 256];
    let mut n = 1024;
    while n <= max_n {
        ns.push(n);
        n *= 2;
    }

    println!("Figure 7 — DNC vs SDNC, T={t_steps} fwd+bwd (word 32, 4 heads)\n");
    let mut table = Table::new(&["model", "N", "time/ep", "total mem", "speedup", "mem ratio"]);
    let mut results = Vec::new();
    for &n in &ns {
        let mut stats = Vec::new();
        for kind in [CoreKind::Dnc, CoreKind::Sdnc] {
            // Total memory including init: measure construction + episode.
            let region = MemRegion::start();
            let mut rng = Rng::new(3);
            let mut core = build_core(kind, &config(n), &mut rng);
            core.reset();
            let x = vec![0.5f32; 8];
            let dy = vec![0.1f32; 8];
            let time = measure(2, || {
                core.reset();
                for _ in 0..t_steps {
                    core.forward(&x);
                }
                for _ in 0..t_steps {
                    core.backward(&dy);
                }
                core.end_episode();
            })
            .min;
            let mem = region.peak_overhead();
            drop(core);
            stats.push((kind, time, mem));
        }
        let (_, t_dnc, m_dnc) = stats[0];
        let (_, t_sdnc, m_sdnc) = stats[1];
        for (kind, time, mem) in &stats {
            table.row(vec![
                format!("{kind:?}"),
                n.to_string(),
                fmt_time(*time),
                fmt_bytes(*mem),
                if matches!(kind, CoreKind::Sdnc) {
                    format!("{:.0}x", t_dnc / t_sdnc)
                } else {
                    "1x".into()
                },
                if matches!(kind, CoreKind::Sdnc) {
                    format!("{:.0}x", m_dnc as f64 / (m_sdnc.max(1) as f64))
                } else {
                    "1x".into()
                },
            ]);
            results.push(Json::obj(vec![
                ("model", Json::str(format!("{kind:?}"))),
                ("n", Json::num(n as f64)),
                ("seconds_per_episode", Json::num(*time)),
                ("total_bytes", Json::num(*mem as f64)),
            ]));
        }
    }
    table.print();
    println!("\nexpectation: speedup and memory ratio grow ~quadratically with N (paper @2048: ~440x time, ~240x memory)");
    save_results("fig7_sdnc", Json::arr(results));
}
