//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **K sweep** — the paper (Supp C) tried K ∈ {4, 8, 16} and "found no
//!   significant difference"; we sweep K on associative recall.
//! * **ANN backend** — linear vs kd-forest vs LSH at equal settings:
//!   learning quality (ANN recall failures would show up as worse loss)
//!   and per-step speed.
//! * **usage threshold δ** — §3.2's δ (default 0.005) gates which accesses
//!   refresh a word's LRA position.
//! * **kd-forest checks** — the FLANN quality/speed knob from Fig 1a.
//!
//!     cargo bench --bench ablations [-- --updates N]

use sam::bench::{fmt_time, measure, save_results, Table};
use sam::prelude::*;
use sam::util::json::Json;

fn train_best_loss(cfg: &CoreConfig, task: &dyn Task, level: usize, updates: usize) -> f64 {
    let mut rng = Rng::new(cfg.seed);
    let core = build_core(CoreKind::Sam, cfg, &mut rng);
    let mut trainer = Trainer::new(
        core,
        Box::new(RmsProp::new(1e-3)),
        TrainConfig {
            batch: 4,
            updates,
            log_every: (updates / 8).max(1),
            seed: cfg.seed,
            verbose: false,
            ..TrainConfig::default()
        },
    );
    let mut cur = Curriculum::fixed(level);
    trainer.run(task, &mut cur).best_loss()
}

fn step_speed(cfg: &CoreConfig) -> f64 {
    let mut rng = Rng::new(cfg.seed);
    let mut core = build_core(CoreKind::Sam, cfg, &mut rng);
    let x = vec![0.5f32; cfg.x_dim];
    let dy = vec![0.1f32; cfg.y_dim];
    measure(2, || {
        core.reset();
        for _ in 0..10 {
            core.forward(&x);
        }
        for _ in 0..10 {
            core.backward(&dy);
        }
        core.end_episode();
    })
    .min
        / 10.0
}

fn main() {
    let args = Args::from_env();
    let updates = args.usize_or("updates", 200);
    let task = AssociativeRecall::new(6);
    let base = CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: 48,
        heads: 2,
        word: 16,
        mem_words: 4096,
        k: 4,
        ann: AnnKind::Linear,
        seed: 31,
        ..CoreConfig::default()
    };
    let mut results = Vec::new();

    println!("Ablation 1 — sparse reads K (paper Supp C: K∈{{4,8,16}} indistinguishable)\n");
    let mut t = Table::new(&["K", "best loss", "time/step"]);
    for k in [2usize, 4, 8, 16] {
        let cfg = CoreConfig { k, ..base.clone() };
        let loss = train_best_loss(&cfg, &task, 4, updates);
        let speed = step_speed(&cfg);
        t.row(vec![k.to_string(), format!("{loss:.3}"), fmt_time(speed)]);
        results.push(Json::obj(vec![
            ("ablation", Json::str("k")),
            ("k", Json::num(k as f64)),
            ("best_loss", Json::num(loss)),
            ("s_per_step", Json::num(speed)),
        ]));
    }
    t.print();

    println!("\nAblation 2 — ANN backend (quality + speed at N=4096)\n");
    let mut t = Table::new(&["ann", "best loss", "time/step"]);
    for (label, ann) in [
        ("linear", AnnKind::Linear),
        ("kd-forest", AnnKind::KdForest),
        ("lsh", AnnKind::Lsh),
    ] {
        let cfg = CoreConfig { ann, ..base.clone() };
        let loss = train_best_loss(&cfg, &task, 4, updates);
        let speed = step_speed(&cfg);
        t.row(vec![label.to_string(), format!("{loss:.3}"), fmt_time(speed)]);
        results.push(Json::obj(vec![
            ("ablation", Json::str("ann")),
            ("backend", Json::str(label)),
            ("best_loss", Json::num(loss)),
            ("s_per_step", Json::num(speed)),
        ]));
    }
    t.print();

    println!("\nAblation 3 — usage threshold δ (paper default 0.005)\n");
    let mut t = Table::new(&["delta", "best loss"]);
    for delta in [0.0f32, 0.005, 0.05, 0.5] {
        let cfg = CoreConfig { delta, ..base.clone() };
        let loss = train_best_loss(&cfg, &task, 4, updates);
        t.row(vec![format!("{delta}"), format!("{loss:.3}")]);
        results.push(Json::obj(vec![
            ("ablation", Json::str("delta")),
            ("delta", Json::num(delta as f64)),
            ("best_loss", Json::num(loss)),
        ]));
    }
    t.print();

    println!("\nAblation 4 — kd-forest `checks` budget (speed/recall trade, Fig 1a)\n");
    let mut t = Table::new(&["checks", "time/step", "recall@4 vs exact"]);
    {
        use sam::ann::{AnnIndex, KdForest, LinearIndex};
        let n = 8192;
        let dim = 16;
        let mut rng = Rng::new(7);
        let pts: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();
        let mut exact = LinearIndex::new(n, dim);
        for (i, p) in pts.iter().enumerate() {
            exact.insert(i, p);
        }
        for checks in [8usize, 32, 128, 512] {
            let mut forest = KdForest::new(n, dim, 4, checks, 10 * n, 1);
            for (i, p) in pts.iter().enumerate() {
                forest.insert(i, p);
            }
            forest.rebuild();
            let mut hits = 0;
            let mut total = 0;
            let queries: Vec<Vec<f32>> = (0..32)
                .map(|qi| {
                    pts[(qi * 37) % n]
                        .iter()
                        .map(|x| x + 0.1 * rng.normal())
                        .collect()
                })
                .collect();
            let speed = measure(3, || {
                for q in &queries {
                    std::hint::black_box(forest.query(q, 4));
                }
            })
            .min
                / 32.0;
            for q in &queries {
                let approx: std::collections::HashSet<usize> =
                    forest.query(q, 4).into_iter().map(|(i, _)| i).collect();
                for (i, _) in exact.query(q, 4) {
                    total += 1;
                    if approx.contains(&i) {
                        hits += 1;
                    }
                }
            }
            let recall = hits as f64 / total as f64;
            t.row(vec![checks.to_string(), fmt_time(speed), format!("{recall:.2}")]);
            results.push(Json::obj(vec![
                ("ablation", Json::str("checks")),
                ("checks", Json::num(checks as f64)),
                ("recall", Json::num(recall)),
                ("s_per_query", Json::num(speed)),
            ]));
        }
    }
    t.print();
    save_results("ablations", Json::arr(results));
}
