//! Figure 4: Omniglot one-shot classification test error vs number of
//! character classes, for models trained with the exponential curriculum.
//!
//! Paper finding: all MANNs beat chance far beyond their training lengths
//! (trained ≤ ~130-char sequences, tested to ~500 chars ≈ 5000 steps);
//! SAM is best (< 0.2 errors at 100 chars), the paper attributing the gap
//! to its much larger usable memory.
//!
//! Uses the documented synthetic-prototype substitution for the Omniglot
//! images (DESIGN.md §3).
//!
//!     cargo bench --bench fig4_omniglot [-- --paper-scale]

use sam::bench::{save_results, Table};
use sam::prelude::*;
use sam::util::json::Json;

fn main() {
    let args = Args::from_env();
    let paper = args.has("paper-scale");
    let updates = args.usize_or("updates", if paper { 10_000 } else { 2000 });
    let max_classes = if paper { 32 } else { 12 };
    let embed = if paper { 64 } else { 16 };
    let task = OmniglotTask::new(embed, max_classes);

    let entries: Vec<(&str, CoreKind, usize)> = vec![
        ("LSTM", CoreKind::Lstm, 64),
        ("DAM", CoreKind::Dam, 64),
        ("SAM", CoreKind::Sam, if paper { 1 << 16 } else { 1 << 12 }),
    ];

    println!("Figure 4 — one-shot classification error vs classes ({updates} updates)\n");
    let eval_classes: Vec<usize> = if paper {
        vec![4, 8, 16, 32]
    } else {
        vec![3, 6, 9, 12] // 12 > training ceiling: generalization column
    };
    let train_max = if paper { 16 } else { 6 };

    let mut headers: Vec<String> = vec!["model".into()];
    headers.extend(eval_classes.iter().map(|c| format!("err@{c}")));
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    let mut results = Vec::new();
    for (label, kind, mem) in &entries {
        let cfg = CoreConfig {
            x_dim: task.x_dim(),
            y_dim: task.y_dim(),
            hidden: if paper { 100 } else { 48 },
            heads: 2,
            word: if paper { 32 } else { 16 },
            mem_words: *mem,
            k: 4,
            ann: AnnKind::Linear,
            seed: 9,
            ..CoreConfig::default()
        };
        let mut rng = Rng::new(9);
        let core = build_core(*kind, &cfg, &mut rng);
        let mut trainer = Trainer::new(
            core,
            Box::new(RmsProp::new(if paper { 1e-4 } else { 3e-3 })),
            TrainConfig {
                batch: 4,
                updates,
                log_every: (updates / 10).max(1),
                seed: 9,
                verbose: false,
                ..TrainConfig::default()
            },
        );
        // Exponential curriculum over class count (paper: double chars on
        // threshold).
        let mut cur = Curriculum::exponential(task.base_level(), train_max, 1.2);
        cur.patience = 10;
        trainer.run(&task, &mut cur);
        let mut row = vec![label.to_string()];
        for &c in &eval_classes {
            let err = trainer.evaluate(&task, c, if paper { 20 } else { 8 }, 1234 + c as u64);
            row.push(format!("{err:.3}"));
            results.push(Json::obj(vec![
                ("model", Json::str(*label)),
                ("classes", Json::num(c as f64)),
                ("error", Json::num(err)),
            ]));
        }
        table.row(row);
    }
    table.print();
    let chance = 1.0 - 1.0 / max_classes as f64;
    println!("\nchance error ≈ {chance:.3}; trained to ≤{train_max} classes — rightmost columns are beyond-training generalization");
    println!("expectation: MANNs ≪ chance everywhere, SAM lowest (paper Fig 4)");
    save_results("fig4_omniglot", Json::arr(results));
}
