//! Figure 1a: wall-clock time of a single forward+backward pass vs memory
//! size N, for NTM / DAM / SAM-linear / SAM-kdtree / SAM-LSH / SAM-HNSW.
//!
//! Paper (Supp E): LSTM-100 controller, word size 32, 4 access heads.
//! Paper headline: at N = 1M, NTM takes 12 s vs SAM 7 ms (~1600×).
//! Expectation here: dense models scale linearly in N, SAM stays flat
//! (linear-index SAM grows slowly: the O(N) scan has a tiny constant).
//!
//! Also measures Supp C's data-parallel training: the same seed must give
//! bit-identical losses at every worker count (deterministic fixed-order
//! reduction), with wall-clock falling as workers are added.
//!
//! And the sharded-memory scale section (→ `BENCH_shard.json` at the repo
//! root): 4-head `query_many` wall-clock at N ∈ {64k, 256k, 1M} across
//! S ∈ {1,2,4,8} shards, with the S=1→4 monotonicity verdict at the
//! largest N recorded in the JSON. `-- --shard-only` runs just that
//! section at full N (CI's bench-smoke leg).
//!
//! And the ANN-backend comparison (→ `BENCH_ann.json`): raw per-query
//! latency of linear/kdtree/lsh/hnsw at N ∈ {64k, 256k, 1M}, with the
//! sub-linear-scaling verdict for hnsw (its 1M/64k time ratio must sit well
//! below the 15.6× row ratio). `-- --ann-only` runs just that section at
//! full N (CI's bench-smoke leg).
//!
//!     cargo bench --bench fig1_speed [-- --paper-scale --workers 4 | --shard-only | --ann-only]

use sam::bench::{fmt_time, measure, save_bench_root, save_results, Table};
use sam::memory::sharded::ShardedMemoryEngine;
use sam::prelude::*;
use sam::tensor::csr::SparseVec;
use sam::tensor::workspace::Workspace;
use sam::util::json::Json;
use sam::util::timer::Timer;

fn step_time(kind: CoreKind, ann: AnnKind, n: usize, t_steps: usize, reps: usize) -> f64 {
    let cfg = CoreConfig {
        x_dim: 8,
        y_dim: 8,
        hidden: 100,
        heads: 4,
        word: 32,
        mem_words: n,
        k: 4,
        ann,
        seed: 1,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(1);
    let mut core = build_core(kind, &cfg, &mut rng);
    let x = vec![0.5f32; 8];
    let dy = vec![0.1f32; 8];
    let stats = measure(reps, || {
        core.reset();
        for _ in 0..t_steps {
            core.forward(&x);
        }
        for _ in 0..t_steps {
            core.backward(&dy);
        }
        core.end_episode();
    });
    stats.min / t_steps as f64 // per fwd+bwd step
}

/// Train SAM-linear for a few updates on `workers` threads; returns
/// (wall seconds, per-log-point losses). ann=Linear keeps episode
/// gradients content-deterministic, so losses must agree bitwise across
/// worker counts (see training::workers).
fn parallel_training_run(workers: usize, updates: usize) -> (f64, Vec<f64>) {
    let task = CopyTask::new(4);
    let cfg = CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: 48,
        heads: 2,
        word: 16,
        mem_words: 256,
        k: 4,
        ann: AnnKind::Linear,
        seed: 5,
        ..CoreConfig::default()
    };
    let mut factory = |_i: usize| {
        let mut rng = Rng::new(5);
        build_core(CoreKind::Sam, &cfg, &mut rng)
    };
    let mut pt = ParallelTrainer::new(
        &mut factory,
        workers,
        Box::new(RmsProp::new(1e-3)),
        TrainConfig {
            batch: 8,
            updates,
            log_every: 1,
            seed: 5,
            verbose: false,
            ..TrainConfig::default()
        },
    );
    let mut cur = Curriculum::fixed(4);
    let t = Timer::start();
    let log = pt.run(&task, &mut cur);
    (t.elapsed_s(), log.points.iter().map(|p| p.loss).collect())
}

/// Seconds per 4-head batched `query_many` (through the full sharded read
/// path: fan-out, merge, softmax, mixture read) at memory size `n` with
/// `s` shards. The engine gets a few writes first so shard contents and
/// ANN sync are realistic.
fn sharded_query_time(n: usize, s: usize, reps: usize) -> f64 {
    let mut e = ShardedMemoryEngine::new_sparse_from_seeds(
        n,
        32,
        4,
        0.005,
        AnnKind::Linear,
        0xBEEF,
        0xFEED,
        s,
    );
    let mut ws = Workspace::new();
    let word = vec![0.3f32; 32];
    for _ in 0..4 {
        let wts = e.infer_write(0.4, -0.1, &SparseVec::new(), &word, &mut ws);
        ws.recycle_sparse(wts);
    }
    let queries: Vec<Vec<f32>> = (0..4)
        .map(|h| (0..32).map(|j| ((h * 7 + j) as f32 * 0.37).sin()).collect())
        .collect();
    let betas = vec![0.5f32; 4];
    let mut out = Vec::new();
    let stats = measure(reps, || {
        e.read_topk_into(&queries, &betas, &mut out, &mut ws);
        for tk in out.drain(..) {
            ws.recycle_sparse(tk.weights);
            ws.recycle_f32(tk.r);
            e.recycle_content_read(tk.read, &mut ws);
        }
    });
    stats.min
}

/// The tentpole's scale section: sharded `query_many` wall-clock at
/// N ∈ {64k, 256k, 1M} across shard counts, written to `BENCH_shard.json`
/// at the repo root (uploaded by CI). The JSON records whether wall-clock
/// improves monotonically S=1 → max S at the largest N, plus a note naming
/// the machine's parallelism when it does not (e.g. single-vCPU runners
/// cannot parallelize a memory-bound scan, which is expected, not a
/// regression — the merge path is value-identical either way).
fn shard_scale_section(full: bool) {
    let shard_counts = [1usize, 2, 4, 8];
    let ns: &[usize] = if full { &[1 << 16, 1 << 18, 1 << 20] } else { &[1 << 16] };
    println!(
        "\nSharded query_many — 4-head batched read vs N and S (threads avail: {})\n",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    );
    let mut table = Table::new(&["N", "S", "time/query-batch", "vs S=1"]);
    let mut rows = Vec::new();
    let mut monotonic = true;
    let mut note = String::new();
    for &n in ns {
        let mut base = 0.0f64;
        let mut prev = f64::INFINITY;
        for &s in &shard_counts {
            let reps = if n >= 1 << 20 { 3 } else { 5 };
            let t = sharded_query_time(n, s, reps);
            if s == 1 {
                base = t;
            }
            if n == *ns.last().unwrap() && s <= 4 {
                if t > prev {
                    monotonic = false;
                }
                prev = t;
            }
            table.row(vec![
                n.to_string(),
                s.to_string(),
                fmt_time(t),
                format!("{:.2}x", base / t),
            ]);
            rows.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("shards", Json::num(s as f64)),
                ("seconds_per_query_batch", Json::num(t)),
                ("speedup_vs_s1", Json::num(base / t)),
            ]));
        }
    }
    table.print();
    if !monotonic {
        note = format!(
            "wall-clock not monotonic S=1..4 at N={}: {} hardware threads available; \
             a memory-bandwidth-bound scan cannot speed up past the machine's \
             core/bandwidth budget (results are value-identical at every S)",
            ns.last().unwrap(),
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        );
        println!("note: {note}");
    }
    save_bench_root(
        "shard",
        Json::obj(vec![
            ("rows", Json::arr(rows)),
            ("largest_n", Json::num(*ns.last().unwrap() as f64)),
            ("monotonic_s1_to_s4_at_largest_n", Json::Bool(monotonic)),
            ("note", Json::str(&note)),
            (
                "threads_available",
                Json::num(
                    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1) as f64,
                ),
            ),
        ]),
    );
}

/// The HNSW tentpole's acceptance section (→ `BENCH_ann.json`): raw
/// per-query latency of each ANN backend at N ∈ {64k, 256k, 1M} (smaller Ns
/// off `--paper-scale`/`--ann-only`), measured through the batched
/// `query_many_into` hot path on the bare indexes. The JSON records the
/// hnsw sub-linear-scaling verdict: its largest-N/smallest-N per-query time
/// ratio must sit well below the row-count ratio (15.6× for 1M/64k) — a
/// linear-time backend tracks the row ratio, an O(log N) graph tracks
/// log N ≈ 1.25×.
fn ann_backend_section(full: bool) {
    use sam::ann::build_index;
    let (dim, k, heads) = (32usize, 16usize, 4usize);
    let ns: &[usize] = if full { &[1 << 16, 1 << 18, 1 << 20] } else { &[1 << 12, 1 << 14] };
    let kinds: &[(&str, AnnKind)] = &[
        ("linear", AnnKind::Linear),
        ("kdtree", AnnKind::KdForest),
        ("lsh", AnnKind::Lsh),
        ("hnsw", AnnKind::Hnsw),
    ];
    println!("\nANN backends — per-query latency, {heads}-query batch, k={k}, dim={dim}\n");
    let mut table = Table::new(&["backend", "N", "build", "time/query", "vs linear"]);
    let mut rows = Vec::new();
    let mut hnsw_t: Vec<(usize, f64)> = Vec::new();
    for &n in ns {
        // One deterministic point set per N, shared by every backend.
        let mut rng = Rng::new(0xA55 ^ n as u64);
        let mut pts = vec![0.0f32; n * dim];
        rng.fill_normal(&mut pts, 1.0);
        // Queries perturbed around stored rows (the SAM regime; uniformly
        // random queries are the known ANN worst case, not the workload).
        let queries: Vec<Vec<f32>> = (0..heads)
            .map(|h| {
                let base = (h * 65_537) % n;
                pts[base * dim..(base + 1) * dim]
                    .iter()
                    .map(|x| x + 0.1 * rng.normal())
                    .collect()
            })
            .collect();
        let mut linear_t = f64::NAN;
        for &(label, kind) in kinds {
            let bt = Timer::start();
            let mut idx = build_index(kind, n, dim, 0xD1CE);
            for i in 0..n {
                idx.insert(i, &pts[i * dim..(i + 1) * dim]);
            }
            let build_s = bt.elapsed_s();
            let mut out = Vec::new();
            idx.query_many_into(&queries, k, &mut out); // warm the scratch
            let reps = if n >= 1 << 20 { 3 } else { 5 };
            let stats = measure(reps, || idx.query_many_into(&queries, k, &mut out));
            let per_query = stats.min / heads as f64;
            if kind == AnnKind::Linear {
                linear_t = per_query;
            }
            if kind == AnnKind::Hnsw {
                hnsw_t.push((n, per_query));
            }
            table.row(vec![
                label.to_string(),
                n.to_string(),
                fmt_time(build_s),
                fmt_time(per_query),
                format!("{:.1}x", linear_t / per_query),
            ]);
            rows.push(Json::obj(vec![
                ("backend", Json::str(label)),
                ("n", Json::num(n as f64)),
                ("build_s", Json::num(build_s)),
                ("seconds_per_query", Json::num(per_query)),
            ]));
        }
    }
    table.print();
    let (n_min, t_min) = hnsw_t[0];
    let (n_max, t_max) = *hnsw_t.last().unwrap();
    let row_ratio = n_max as f64 / n_min as f64;
    let time_ratio = t_max / t_min.max(1e-12);
    let sublinear = time_ratio < row_ratio / 2.0;
    println!(
        "\nhnsw scaling: time(N={n_max})/time(N={n_min}) = {time_ratio:.2}x vs row ratio \
         {row_ratio:.1}x -> {}",
        if sublinear { "sub-linear" } else { "NOT SUB-LINEAR" }
    );
    save_bench_root(
        "ann",
        Json::obj(vec![
            ("rows", Json::arr(rows)),
            ("largest_n", Json::num(n_max as f64)),
            ("smallest_n", Json::num(n_min as f64)),
            ("hnsw_time_ratio_largest_vs_smallest", Json::num(time_ratio)),
            ("row_ratio", Json::num(row_ratio)),
            ("hnsw_sublinear", Json::Bool(sublinear)),
        ]),
    );
}

fn main() {
    let args = Args::from_env();
    let paper = args.has("paper-scale");
    let t_steps = args.usize_or("steps", 10);

    // CI's bench-smoke leg: just the sharded scale section (full N sweep up
    // to 1M), skipping the Figure 1a model sweep.
    if args.has("shard-only") {
        shard_scale_section(true);
        return;
    }
    // CI's ANN-backend leg: just the backend comparison at full N.
    if args.has("ann-only") {
        ann_backend_section(true);
        return;
    }

    // (label, kind, ann, max N) — dense models stop earlier: their per-step
    // cost AND snapshot memory are O(N) (NTM additionally snapshots per head).
    let dense_max = if paper { 1 << 16 } else { 1 << 12 };
    let sparse_max = if paper { 1 << 21 } else { 1 << 16 };
    let models: Vec<(&str, CoreKind, AnnKind, usize)> = vec![
        ("NTM", CoreKind::Ntm, AnnKind::Linear, dense_max),
        ("DAM", CoreKind::Dam, AnnKind::Linear, dense_max),
        ("SAM linear", CoreKind::Sam, AnnKind::Linear, sparse_max),
        ("SAM kd-tree", CoreKind::Sam, AnnKind::KdForest, sparse_max),
        ("SAM LSH", CoreKind::Sam, AnnKind::Lsh, sparse_max),
        ("SAM HNSW", CoreKind::Sam, AnnKind::Hnsw, sparse_max),
    ];

    let mut ns = Vec::new();
    let mut n = 64;
    while n <= sparse_max {
        ns.push(n);
        n *= 4;
    }

    println!("Figure 1a — forward+backward wall-clock per step vs N (T={t_steps})\n");
    let mut table = Table::new(&["model", "N", "time/step", "vs NTM@N"]);
    let mut results = Vec::new();
    let mut ntm_at: std::collections::HashMap<usize, f64> = Default::default();
    for (label, kind, ann, max_n) in &models {
        for &n in ns.iter().filter(|&&n| n <= *max_n) {
            let reps = if n >= 1 << 18 { 1 } else { 2 };
            let t = step_time(*kind, *ann, n, t_steps, reps);
            if *label == "NTM" {
                ntm_at.insert(n, t);
            }
            let speedup = ntm_at
                .get(&n)
                .map(|ntm| format!("{:.1}x", ntm / t))
                .unwrap_or_else(|| "-".into());
            table.row(vec![label.to_string(), n.to_string(), fmt_time(t), speedup]);
            results.push(Json::obj(vec![
                ("model", Json::str(*label)),
                ("n", Json::num(n as f64)),
                ("seconds_per_step", Json::num(t)),
            ]));
        }
    }
    table.print();
    // Headline ratio at the largest common N.
    let n_big = *ns.iter().filter(|&&n| n <= dense_max).max().unwrap();
    let sam_big = step_time(CoreKind::Sam, AnnKind::KdForest, n_big, t_steps, 2);
    if let Some(ntm_big) = ntm_at.get(&n_big) {
        println!(
            "\nheadline @ N={n_big}: NTM {} vs SAM(kd) {} -> {:.0}x speedup (paper: ~100-1600x as N grows)",
            fmt_time(*ntm_big),
            fmt_time(sam_big),
            ntm_big / sam_big
        );
    }
    // --- Supp C: data-parallel training throughput + determinism ---------
    let max_workers = args.usize_or("workers", 4).max(1);
    let train_updates = args.usize_or("train-updates", 6);
    println!("\nSupp C — data-parallel training (SAM linear, batch 8, {train_updates} updates)\n");
    let mut ptable = Table::new(&["workers", "wall", "speedup vs 1", "losses bit-identical"]);
    let mut presults = Vec::new();
    let mut base_wall = 0.0f64;
    let mut base_losses: Vec<f64> = Vec::new();
    let mut w = 1;
    while w <= max_workers {
        let (wall, losses) = parallel_training_run(w, train_updates);
        if w == 1 {
            base_wall = wall;
            base_losses = losses.clone();
        }
        let identical = losses.len() == base_losses.len()
            && losses
                .iter()
                .zip(&base_losses)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        ptable.row(vec![
            w.to_string(),
            fmt_time(wall),
            format!("{:.2}x", base_wall / wall),
            if identical { "yes".into() } else { "NO — DETERMINISM BUG".into() },
        ]);
        presults.push(Json::obj(vec![
            ("workers", Json::num(w as f64)),
            ("wall_s", Json::num(wall)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        w *= 2;
    }
    ptable.print();
    results.extend(presults);

    // Sharded memory scale section (BENCH_shard.json): full N sweep to 1M
    // at --paper-scale, the 64k point otherwise.
    shard_scale_section(paper);

    // ANN backend comparison (BENCH_ann.json): full N sweep to 1M at
    // --paper-scale, smaller Ns otherwise.
    ann_backend_section(paper);

    save_results("fig1_speed", Json::arr(results));
}
