//! Figure 3: curriculum training — how far can each model climb the
//! exponentially-increasing difficulty ladder in a fixed budget?
//!
//! Paper setup (§4.3): dense models (NTM, DAM) get 64 memory words, sparse
//! models get 2×10⁶ so all use roughly the same physical memory; difficulty
//! doubles when training loss drops below a threshold; level sampled
//! U(base, h). Finding: SAM advances further on every task (recall > 4000).
//!
//!     cargo bench --bench fig3_curriculum [-- --paper-scale --updates N]

use sam::bench::{save_results, Table};
use sam::prelude::*;
use sam::util::json::Json;

struct Entry {
    label: &'static str,
    kind: CoreKind,
    ann: AnnKind,
    mem_words: usize,
}

fn main() {
    let args = Args::from_env();
    let paper = args.has("paper-scale");
    let updates = args.usize_or("updates", if paper { 20_000 } else { 2000 });
    // Dense memory small, sparse memory huge — the paper's equal-physical-
    // memory comparison (64 vs 2e6; reduced by default).
    let dense_n = 64;
    let sparse_n = if paper { 1 << 21 } else { 1 << 14 };
    let entries = [
        Entry { label: "NTM", kind: CoreKind::Ntm, ann: AnnKind::Linear, mem_words: dense_n },
        Entry { label: "DAM", kind: CoreKind::Dam, ann: AnnKind::Linear, mem_words: dense_n },
        Entry { label: "SAM linear", kind: CoreKind::Sam, ann: AnnKind::Linear, mem_words: sparse_n },
        Entry { label: "SAM ANN", kind: CoreKind::Sam, ann: AnnKind::KdForest, mem_words: sparse_n },
    ];
    let tasks: Vec<(Box<dyn Task>, usize, f64)> = vec![
        // (task, base level, curriculum loss threshold per scored step)
        // Reduced-scale thresholds sit just under each task's early
        // plateau so advances measure continued progress, not convergence
        // (paper-scale uses strict thresholds over far longer training).
        (Box::new(AssociativeRecall::new(6)), 2, 3.0),
        (Box::new(CopyTask::new(6)), 2, 3.4),
        (Box::new(PrioritySort::new(6)), 4, 3.8),
    ];

    println!("Figure 3 — exponential curriculum: final difficulty reached ({updates} updates)\n");
    let mut results = Vec::new();
    for (task, base, threshold) in &tasks {
        let mut table = Table::new(&["model", "final level", "advances", "final loss"]);
        for e in &entries {
            let cfg = CoreConfig {
                x_dim: task.x_dim(),
                y_dim: task.y_dim(),
                hidden: if paper { 100 } else { 48 },
                heads: 2,
                word: if paper { 32 } else { 16 },
                mem_words: e.mem_words,
                k: 4,
                ann: e.ann,
                seed: 5,
                ..CoreConfig::default()
            };
            let mut rng = Rng::new(5);
            let core = build_core(e.kind, &cfg, &mut rng);
            let mut trainer = Trainer::new(
                core,
                Box::new(RmsProp::new(if paper { 1e-4 } else { 3e-3 })),
                TrainConfig {
                    batch: 4,
                    updates,
                    log_every: (updates / 10).max(1),
                    seed: 5,
                    verbose: false,
                    ..TrainConfig::default()
                },
            );
            let mut cur = Curriculum::exponential(*base, 1 << 20, *threshold);
            cur.patience = 10;
            let log = trainer.run(task.as_ref(), &mut cur);
            table.row(vec![
                e.label.to_string(),
                log.final_level.to_string(),
                cur.advances.to_string(),
                format!("{:.3}", log.points.last().unwrap().loss),
            ]);
            results.push(Json::obj(vec![
                ("task", Json::str(task.name())),
                ("model", Json::str(e.label)),
                ("final_level", Json::num(log.final_level as f64)),
            ]));
        }
        println!("task: {} (threshold {threshold})", task.name());
        table.print();
        println!();
    }
    println!("expectation: SAM ≥ dense models on final level for every task (paper Fig 3)");
    save_results("fig3_curriculum", Json::arr(results));
}
