//! Figure 2: training curves for sparse (SAM) and dense (DAM, NTM) models
//! plus the LSTM baseline on the three NTM algorithmic tasks — copy,
//! associative recall, priority sort.
//!
//! Paper finding: SAM trains comparably on copy and reaches asymptotic
//! error *faster* on associative recall and priority sort — sparsity does
//! not hurt data efficiency.
//!
//! Default scale is reduced (1-core container); pass --paper-scale for the
//! paper's LSTM-100 / batch-8 configuration.
//!
//!     cargo bench --bench fig2_learning [-- --paper-scale --updates N]

use sam::bench::{save_results, Table};
use sam::prelude::*;
use sam::util::json::Json;

fn run(
    kind: CoreKind,
    task: &dyn Task,
    level: usize,
    updates: usize,
    paper: bool,
    seed: u64,
) -> sam::training::TrainLog {
    let cfg = CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: if paper { 100 } else { 48 },
        heads: if paper { 4 } else { 2 },
        word: if paper { 32 } else { 16 },
        mem_words: if paper { 128 } else { 64 },
        k: 4,
        ann: AnnKind::Linear,
        seed,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(seed);
    let core = build_core(kind, &cfg, &mut rng);
    let mut trainer = Trainer::new(
        core,
        Box::new(RmsProp::new(if paper { 1e-4 } else { 1e-3 })),
        TrainConfig {
            batch: if paper { 8 } else { 4 },
            updates,
            log_every: (updates / 10).max(1),
            seed,
            verbose: false,
            ..TrainConfig::default()
        },
    );
    let mut cur = Curriculum::fixed(level);
    trainer.run(task, &mut cur)
}

fn main() {
    let args = Args::from_env();
    let paper = args.has("paper-scale");
    let updates = args.usize_or("updates", if paper { 5000 } else { 250 });
    let seeds = args.usize_or("seeds", if paper { 5 } else { 2 });

    let tasks: Vec<(Box<dyn Task>, usize)> = vec![
        (Box::new(CopyTask::new(6)), if paper { 20 } else { 6 }),
        (Box::new(AssociativeRecall::new(6)), if paper { 6 } else { 4 }),
        (Box::new(PrioritySort::new(6)), if paper { 20 } else { 8 }),
    ];
    let models = [CoreKind::Lstm, CoreKind::Ntm, CoreKind::Dam, CoreKind::Sam];

    println!("Figure 2 — training curves (loss/step at checkpoints), {seeds} seed(s)\n");
    let mut all = Vec::new();
    for (task, level) in &tasks {
        let mut table = Table::new(&["model", "start", "25%", "50%", "75%", "final", "best"]);
        for kind in models {
            // average curves over seeds
            let mut curves: Vec<Vec<f64>> = Vec::new();
            for s in 0..seeds {
                let log = run(kind, task.as_ref(), *level, updates, paper, 42 + s as u64);
                curves.push(log.points.iter().map(|p| p.loss).collect());
            }
            let len = curves[0].len();
            let avg: Vec<f64> = (0..len)
                .map(|i| curves.iter().map(|c| c[i]).sum::<f64>() / curves.len() as f64)
                .collect();
            let pick = |f: f64| avg[((len - 1) as f64 * f) as usize];
            let best = avg.iter().cloned().fold(f64::INFINITY, f64::min);
            table.row(vec![
                format!("{kind:?}"),
                format!("{:.3}", avg[0]),
                format!("{:.3}", pick(0.25)),
                format!("{:.3}", pick(0.5)),
                format!("{:.3}", pick(0.75)),
                format!("{:.3}", avg[len - 1]),
                format!("{:.3}", best),
            ]);
            all.push(Json::obj(vec![
                ("task", Json::str(task.name())),
                ("model", Json::str(format!("{kind:?}"))),
                ("curve", Json::Arr(avg.iter().map(|&x| Json::num(x)).collect())),
            ]));
        }
        println!("task: {} (level {level})", task.name());
        table.print();
        println!();
    }
    println!("expectation: SAM's final/best ≈ or < dense models (paper: sparse trains comparably, faster on recall/sort)");
    save_results("fig2_learning", Json::arr(all));
}
