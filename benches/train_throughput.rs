//! Training-throughput harness for the threads × batch path: episodes/s
//! for the serial `Trainer`, the threads-only `ParallelTrainer`, and the
//! `FusedTrainer` (`--workers W --batch-fuse B`) at several lane counts
//! and memory sizes. All three follow the same canonical batch protocol,
//! so the comparison is pure mechanism overhead vs fusion payoff.
//!
//! Writes `BENCH_train.json` at the repo root (CI uploads it as an
//! artifact next to BENCH_kernels.json / BENCH_serve.json), including a
//! `verdict` object: threads × batch at B=8 on the largest memory must
//! clear ≥ 1.5× the threads-only episode rate.
//!
//!     cargo bench --bench train_throughput [-- --smoke] [-- --workers 4]

use sam::bench::{save_bench_root, Table};
use sam::cores::{CoreConfig, CoreKind};
use sam::prelude::*;
use sam::training::TrainLog;
use sam::util::json::Json;
use sam::util::metrics;
use sam::util::timer::Timer;

/// The B=8 threads×batch rate must clear this multiple of threads-only.
const VERDICT_MIN_SPEEDUP: f64 = 1.5;
const VERDICT_B: usize = 8;

fn core_cfg(task: &dyn Task, mem_words: usize, smoke: bool) -> CoreConfig {
    CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: if smoke { 32 } else { 64 },
        heads: 4,
        word: 16,
        mem_words,
        k: 4,
        ann: AnnKind::Linear,
        seed: 21,
        ..CoreConfig::default()
    }
}

fn train_cfg(updates: usize, batch: usize, batch_fuse: usize) -> TrainConfig {
    TrainConfig {
        lr: 1e-4,
        batch,
        updates,
        log_every: updates,
        seed: 21,
        verbose: false,
        batch_fuse,
    }
}

fn eps_per_s(log: &TrainLog, elapsed: f64) -> f64 {
    if elapsed > 0.0 {
        log.total_episodes as f64 / elapsed
    } else {
        0.0
    }
}

fn run_serial(task: &dyn Task, cfg: &CoreConfig, tcfg: TrainConfig, level: usize) -> f64 {
    let mut t = Trainer::new(
        build_core(CoreKind::Sam, cfg, &mut Rng::new(cfg.seed)),
        Box::new(RmsProp::new(1e-4)),
        tcfg,
    );
    let mut cur = Curriculum::fixed(level);
    let timer = Timer::start();
    let log = t.run(task, &mut cur);
    eps_per_s(&log, timer.elapsed_s())
}

fn run_threads(
    task: &dyn Task,
    cfg: &CoreConfig,
    tcfg: TrainConfig,
    workers: usize,
    level: usize,
) -> f64 {
    let mut factory = |_i: usize| build_core(CoreKind::Sam, cfg, &mut Rng::new(cfg.seed));
    let mut pt = ParallelTrainer::new(&mut factory, workers, Box::new(RmsProp::new(1e-4)), tcfg);
    let mut cur = Curriculum::fixed(level);
    let timer = Timer::start();
    let log = pt.run(task, &mut cur);
    eps_per_s(&log, timer.elapsed_s())
}

fn run_fused(
    task: &dyn Task,
    cfg: &CoreConfig,
    tcfg: TrainConfig,
    workers: usize,
    level: usize,
) -> f64 {
    let mut ft =
        FusedTrainer::new(CoreKind::Sam, cfg, workers, Box::new(RmsProp::new(1e-4)), tcfg);
    let mut cur = Curriculum::fixed(level);
    let timer = Timer::start();
    let log = ft.run(task, &mut cur);
    eps_per_s(&log, timer.elapsed_s())
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let workers = args.usize_or("workers", if smoke { 2 } else { 4 });
    let updates = args.usize_or("updates", if smoke { 3 } else { 12 });
    let level = args.usize_or("level", if smoke { 4 } else { 8 });
    let lane_counts: Vec<usize> = vec![1, 4, 8];
    // Episodes per update: enough to fill every worker's lanes at the
    // largest B, so the fused groups actually run full.
    let batch = workers * *lane_counts.last().unwrap();
    let mem_sizes: Vec<usize> = if smoke { vec![1 << 10] } else { vec![1 << 14, 1 << 16] };

    let task = CopyTask::new(8);
    let mut table = Table::new(&["N", "mode", "episodes/s", "vs threads-only"]);
    let mut config_rows = Vec::new();
    let mut verdict_speedup = 0.0f64;
    let mut verdict_n = 0usize;

    for &n in &mem_sizes {
        let cfg = core_cfg(&task, n, smoke);
        let serial = run_serial(&task, &cfg, train_cfg(updates, batch, 1), level);
        let threads = run_threads(&task, &cfg, train_cfg(updates, batch, 1), workers, level);
        table.row(vec![n.to_string(), "serial".into(), format!("{serial:.1}"), "-".into()]);
        table.row(vec![
            n.to_string(),
            format!("threads x{workers}"),
            format!("{threads:.1}"),
            "1.00x".into(),
        ]);
        let mut lane_rows = Vec::new();
        for &b in &lane_counts {
            let fused = run_fused(&task, &cfg, train_cfg(updates, batch, b), workers, level);
            let speedup = if threads > 0.0 { fused / threads } else { 0.0 };
            table.row(vec![
                n.to_string(),
                format!("threads x{workers} b{b}"),
                format!("{fused:.1}"),
                format!("{speedup:.2}x"),
            ]);
            lane_rows.push(Json::obj(vec![
                ("batch_fuse", Json::num(b as f64)),
                ("episodes_per_s", Json::num(fused)),
                ("speedup_vs_threads", Json::num(speedup)),
            ]));
            if b == VERDICT_B {
                // Verdict taken at the largest memory: that is where the
                // merged ANN dispatch and fused GEMVs have the most to win.
                verdict_speedup = speedup;
                verdict_n = n;
            }
        }
        config_rows.push(Json::obj(vec![
            ("mem_words", Json::num(n as f64)),
            ("serial_episodes_per_s", Json::num(serial)),
            ("threads_episodes_per_s", Json::num(threads)),
            ("fused", Json::Arr(lane_rows)),
        ]));
    }
    table.print();

    let pass = verdict_speedup >= VERDICT_MIN_SPEEDUP;
    println!(
        "\nverdict: threads x{workers} b{VERDICT_B} at N={verdict_n}: {verdict_speedup:.2}x \
         vs threads-only (need >= {VERDICT_MIN_SPEEDUP:.1}x) -> {}",
        if pass { "PASS" } else { "FAIL" }
    );

    save_bench_root(
        "train",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("workers", Json::num(workers as f64)),
            ("updates", Json::num(updates as f64)),
            ("batch", Json::num(batch as f64)),
            ("level", Json::num(level as f64)),
            ("configs", Json::Arr(config_rows)),
            (
                "verdict",
                Json::obj(vec![
                    ("batch_fuse", Json::num(VERDICT_B as f64)),
                    ("mem_words", Json::num(verdict_n as f64)),
                    ("speedup_vs_threads", Json::num(verdict_speedup)),
                    ("min_required", Json::num(VERDICT_MIN_SPEEDUP)),
                    ("pass", Json::Bool(pass)),
                ]),
            ),
            // Where the tick time went, from the in-process registry: one
            // summary per F/B phase plus the gradient-reduce histogram,
            // accumulated over every configuration this run trained.
            (
                "metrics",
                Json::obj(vec![
                    (
                        "grad_reduce_us",
                        metrics::hist_summary_json(&metrics::TRAIN_GRAD_REDUCE_US),
                    ),
                    (
                        "fwd_phase_us",
                        Json::Arr(
                            metrics::TRAIN_FWD_PHASE_US
                                .iter()
                                .map(metrics::hist_summary_json)
                                .collect(),
                        ),
                    ),
                    (
                        "bwd_phase_us",
                        Json::Arr(
                            metrics::TRAIN_BWD_PHASE_US
                                .iter()
                                .map(metrics::hist_summary_json)
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ]),
    );
}
