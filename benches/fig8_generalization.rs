//! Supplementary Figure 8: length generalization on associative recall.
//!
//! Paper: SAM trained with a curriculum up to difficulty 10,000 still beats
//! chance (48 bits) on sequences of length 200,000 — a 20× extrapolation.
//! Here: train SAM with the exponential curriculum to level L, then
//! evaluate bit errors at multiples of L against the chance line.
//!
//!     cargo bench --bench fig8_generalization [-- --paper-scale]

use sam::bench::{save_results, Table};
use sam::prelude::*;
use sam::util::json::Json;

fn main() {
    let args = Args::from_env();
    let paper = args.has("paper-scale");
    let updates = args.usize_or("updates", if paper { 50_000 } else { 4000 });
    let bits = 6;
    let task = AssociativeRecall::new(bits);

    let cfg = CoreConfig {
        x_dim: task.x_dim(),
        y_dim: task.y_dim(),
        hidden: if paper { 100 } else { 48 },
        heads: 2,
        word: 16,
        mem_words: if paper { 1 << 20 } else { 1 << 14 },
        k: 4,
        ann: AnnKind::KdForest,
        seed: 8,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(8);
    let core = build_core(CoreKind::Sam, &cfg, &mut rng);
    let mut trainer = Trainer::new(
        core,
        Box::new(RmsProp::new(if paper { 1e-4 } else { 3e-3 })),
        TrainConfig {
            batch: 4,
            updates,
            log_every: (updates / 10).max(1),
            seed: 8,
            verbose: false,
            ..TrainConfig::default()
        },
    );
    let max_level = if paper { 10_000 } else { 64 };
    let mut cur = Curriculum::exponential(2, max_level, 0.35);
    cur.patience = 10;
    let log = trainer.run(&task, &mut cur);
    let trained_to = log.final_level;
    println!("Figure 8 — SAM length generalization on associative recall");
    println!("trained with curriculum to level {trained_to} ({} updates)\n", updates);

    let mut table = Table::new(&["eval level", "x trained", "bit errors/ep", "chance"]);
    let chance = bits as f64 * 0.5; // expected wrong bits for a random guess
    let mut results = Vec::new();
    for mult in [1usize, 2, 5, 10, 20] {
        let level = trained_to * mult;
        let errs = trainer.evaluate(&task, level, if paper { 10 } else { 5 }, 777 + mult as u64);
        table.row(vec![
            level.to_string(),
            format!("{mult}x"),
            format!("{errs:.2}"),
            format!("{chance:.1}"),
        ]);
        results.push(Json::obj(vec![
            ("level", Json::num(level as f64)),
            ("mult", Json::num(mult as f64)),
            ("bit_errors", Json::num(errs)),
            ("chance", Json::num(chance)),
        ]));
    }
    table.print();
    println!("\nexpectation: errors stay well below chance out to 20x the trained length (paper: 10k → 200k)");
    save_results("fig8_generalization", Json::arr(results));
}
