//! Perf-regression harness: kernel GFLOP/s and end-to-end step latency.
//!
//! Writes two JSON files at the repo root that every future perf PR is
//! judged against:
//!
//! * `BENCH_kernels.json` — blocked vs reference GFLOP/s for
//!   `gemm`/`gemm_tn`/`gemm_nt`/`gemv` at LSTM-sized shapes (the 256×512 ×
//!   512×256 class the controller's batched backward produces). Acceptance
//!   floor: blocked `gemm_nt`/`gemm_tn` ≥ 2× reference at those shapes.
//! * `BENCH_step.json` — µs per forward+backward step for SAM / SDNC / DAM
//!   at N ∈ {1k, 16k, 64k} (paper-style scaling points).
//!
//!     cargo bench --bench kernels [-- --smoke]
//!
//! `--smoke` runs reduced shapes/reps (CI keeps it under a minute) but
//! still writes both files, tagged `"smoke": true`.

use sam::ann::{AnnIndex, LinearIndex};
use sam::bench::{fmt_time, gflops, measure, save_bench_root, Table};
use sam::prelude::*;
use sam::tensor::matrix::{self, reference, Matrix};
use sam::tensor::rowcodec::RowFormat;
use sam::tensor::simd::{kernel_path, kernel_path_name, KernelPath};
use sam::util::json::Json;

fn random_matrix(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = rng.normal();
    }
    m
}

struct KernelResult {
    kernel: &'static str,
    m: usize,
    k: usize,
    n: usize,
    gflops_blocked: f64,
    gflops_reference: f64,
}

/// Time one (blocked, reference) kernel pair on C += op(A)op(B) shapes.
fn bench_pair(
    kernel: &'static str,
    m: usize,
    k: usize,
    n: usize,
    reps: usize,
    rng: &mut Rng,
    blocked: impl Fn(&mut Matrix, &Matrix, &Matrix),
    refk: impl Fn(&mut Matrix, &Matrix, &Matrix),
    shapes: (usize, usize, usize, usize, usize, usize),
) -> KernelResult {
    let (ar, ac, br, bc, cr, cc) = shapes;
    let a = random_matrix(ar, ac, rng);
    let b = random_matrix(br, bc, rng);
    let mut c = Matrix::zeros(cr, cc);
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let tb = measure(reps, || blocked(&mut c, &a, &b)).min;
    c.fill(0.0);
    let tr = measure(reps, || refk(&mut c, &a, &b)).min;
    KernelResult {
        kernel,
        m,
        k,
        n,
        gflops_blocked: gflops(flops, tb),
        gflops_reference: gflops(flops, tr),
    }
}

fn kernel_suite(smoke: bool) -> Vec<KernelResult> {
    let mut rng = Rng::new(42);
    // LSTM-sized shape class: T×4H ᵀ· T×I backward flush and T×I · (4H×I)ᵀ
    // forward, plus the square GEMM. Smoke shrinks everything 4×.
    let (m, k, n, reps) = if smoke { (64, 128, 64, 3) } else { (256, 512, 256, 7) };
    let mut out = Vec::new();
    out.push(bench_pair(
        "gemm",
        m,
        k,
        n,
        reps,
        &mut rng,
        matrix::gemm,
        reference::gemm,
        (m, k, k, n, m, n),
    ));
    out.push(bench_pair(
        "gemm_tn",
        m,
        k,
        n,
        reps,
        &mut rng,
        matrix::gemm_tn,
        reference::gemm_tn,
        (k, m, k, n, m, n),
    ));
    out.push(bench_pair(
        "gemm_nt",
        m,
        k,
        n,
        reps,
        &mut rng,
        matrix::gemm_nt,
        reference::gemm_nt,
        (m, k, n, k, m, n),
    ));
    // gemv at controller shape (4H × (x + heads·word), H = 100).
    {
        let (gm, gn) = if smoke { (128, 132) } else { (400, 136) };
        let a = random_matrix(gm, gn, &mut rng);
        let x: Vec<f32> = (0..gn).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; gm];
        let flops = 2.0 * gm as f64 * gn as f64;
        let tb = measure(reps * 64, || matrix::gemv(&mut y, &a, &x)).min;
        let tr = measure(reps * 64, || reference::gemv(&mut y, &a, &x)).min;
        out.push(KernelResult {
            kernel: "gemv",
            m: gm,
            k: gn,
            n: 1,
            gflops_blocked: gflops(flops, tb),
            gflops_reference: gflops(flops, tr),
        });
    }
    out
}

/// µs per forward+backward step for one core at memory size N.
fn step_time_us(kind: CoreKind, n: usize, t_steps: usize, reps: usize) -> f64 {
    let cfg = CoreConfig {
        x_dim: 8,
        y_dim: 8,
        hidden: 100,
        heads: 4,
        word: 32,
        mem_words: n,
        k: 4,
        ann: AnnKind::Linear,
        seed: 1,
        ..CoreConfig::default()
    };
    let mut rng = Rng::new(1);
    let mut core = build_core(kind, &cfg, &mut rng);
    let x = vec![0.5f32; 8];
    let dy = vec![0.1f32; 8];
    let mut y = Vec::new();
    // One throwaway episode warms the workspace pools, so the measurement
    // sees the steady state the zero-allocation tests pin.
    let stats = measure(reps, || {
        core.reset();
        for _ in 0..t_steps {
            core.forward_into(&x, &mut y);
        }
        for _ in 0..t_steps {
            core.backward(&dy);
        }
        core.end_episode();
    });
    stats.min / t_steps as f64 * 1e6
}

/// Rows/s for a LinearIndex scan (`query_many_rank_into`) over `n` rows of
/// width `w` stored in `fmt` — the bandwidth-bound ANN hot path that row
/// compaction targets.
fn scan_rows_per_s(fmt: RowFormat, n: usize, w: usize, heads: usize, reps: usize) -> f64 {
    let mut rng = Rng::new(7);
    let mut idx = LinearIndex::with_format(n, w, fmt);
    let mut row = vec![0.0f32; w];
    for i in 0..n {
        for v in row.iter_mut() {
            *v = rng.normal();
        }
        idx.insert(i, &row);
    }
    let queries: Vec<Vec<f32>> =
        (0..heads).map(|_| (0..w).map(|_| rng.normal()).collect()).collect();
    let mut out = Vec::new();
    idx.query_many_rank_into(&queries, 16, &mut out); // warm scratch
    let t = measure(reps, || idx.query_many_rank_into(&queries, 16, &mut out)).min;
    (n * heads) as f64 / t.max(1e-12)
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let t_steps = args.usize_or("steps", 10);
    let vectorized = kernel_path() == KernelPath::Avx2Fma;
    println!("kernel dispatch: {}\n", kernel_path_name());

    // --- kernels ----------------------------------------------------------
    println!("Kernel GFLOP/s — register-blocked vs reference\n");
    let mut ktable = Table::new(&["kernel", "shape", "blocked", "reference", "speedup"]);
    let kernels = kernel_suite(smoke);
    let mut kjson = Vec::new();
    for r in &kernels {
        let speedup = r.gflops_blocked / r.gflops_reference.max(1e-12);
        ktable.row(vec![
            r.kernel.to_string(),
            format!("{}x{}x{}", r.m, r.k, r.n),
            format!("{:.2} GF/s", r.gflops_blocked),
            format!("{:.2} GF/s", r.gflops_reference),
            format!("{speedup:.2}x"),
        ]);
        kjson.push(Json::obj(vec![
            ("kernel", Json::str(r.kernel)),
            ("m", Json::num(r.m as f64)),
            ("k", Json::num(r.k as f64)),
            ("n", Json::num(r.n as f64)),
            ("gflops_blocked", Json::num(r.gflops_blocked)),
            ("gflops_reference", Json::num(r.gflops_reference)),
            ("speedup", Json::num(speedup)),
        ]));
    }
    ktable.print();
    // Acceptance floor: blocked GEMM ≥ 2× the dot-product reference on the
    // vectorized path. Scalar-dispatch machines report the fallback and
    // skip the ratio verdict (the blocked-vs-reference gap there is the
    // old, separately-tracked baseline).
    let gemm_speedup = kernels
        .iter()
        .find(|r| r.kernel == "gemm")
        .map(|r| r.gflops_blocked / r.gflops_reference.max(1e-12))
        .unwrap_or(0.0);
    let gemm_verdict = if !vectorized {
        "skipped (scalar dispatch)".to_string()
    } else if gemm_speedup >= 2.0 {
        "pass".to_string()
    } else {
        format!("fail ({gemm_speedup:.2}x < 2x)")
    };
    println!("\ngemm >=2x verdict: {gemm_verdict}");

    // --- linear-scan bandwidth per row format ------------------------------
    // The ANN scan is bandwidth-bound, so rows/s should track bytes/row:
    // bf16 halves traffic, int8 quarters it (plus one scale per row).
    let (sn, sw, sheads, sreps) = if smoke { (1 << 16, 64, 4, 3) } else { (1 << 20, 64, 4, 5) };
    println!("\nLinear-scan bandwidth (N={sn}, W={sw}, {sheads} heads, k=16)\n");
    let mut scantable = Table::new(&["format", "rows/s", "vs f32"]);
    let mut scanjson = Vec::new();
    let mut rows_per_s = std::collections::BTreeMap::new();
    for fmt in [RowFormat::F32, RowFormat::Bf16, RowFormat::Int8] {
        let rps = scan_rows_per_s(fmt, sn, sw, sheads, sreps);
        rows_per_s.insert(fmt.name(), rps);
        let ratio = rps / rows_per_s["f32"].max(1e-12);
        scantable.row(vec![
            fmt.name().to_string(),
            format!("{:.2}M", rps / 1e6),
            format!("{ratio:.2}x"),
        ]);
        scanjson.push(Json::obj(vec![
            ("row_format", Json::str(fmt.name())),
            ("n", Json::num(sn as f64)),
            ("w", Json::num(sw as f64)),
            ("rows_per_s", Json::num(rps)),
            ("vs_f32", Json::num(ratio)),
        ]));
    }
    scantable.print();
    let bf16_speedup = rows_per_s["bf16"] / rows_per_s["f32"].max(1e-12);
    let scan_verdict = if !vectorized {
        "skipped (scalar dispatch)".to_string()
    } else if bf16_speedup >= 1.7 {
        "pass".to_string()
    } else {
        format!("fail ({bf16_speedup:.2}x < 1.7x)")
    };
    println!("\nbf16 scan >=1.7x verdict: {scan_verdict}");

    save_bench_root(
        "kernels",
        Json::obj(vec![
            ("generated_by", Json::str("benches/kernels.rs")),
            ("smoke", Json::Bool(smoke)),
            ("kernels", Json::arr(kjson)),
            ("gemm_speedup", Json::num(gemm_speedup)),
            ("gemm_verdict", Json::str(gemm_verdict.as_str())),
            ("linear_scan", Json::arr(scanjson)),
            ("scan_bf16_speedup", Json::num(bf16_speedup)),
            ("scan_verdict", Json::str(scan_verdict.as_str())),
        ]),
    );

    // --- end-to-end steps --------------------------------------------------
    // Dense DAM is O(N·W)/step; cap it one size down in smoke mode so CI
    // stays fast.
    let ns: Vec<usize> = if smoke { vec![1 << 10, 1 << 12] } else { vec![1 << 10, 1 << 14, 1 << 16] };
    let reps = if smoke { 1 } else { 2 };
    println!("\nEnd-to-end µs/step (forward+backward, T={t_steps})\n");
    let mut stable = Table::new(&["core", "N", "µs/step"]);
    let mut sjson = Vec::new();
    for (label, kind) in [("sam", CoreKind::Sam), ("sdnc", CoreKind::Sdnc), ("dam", CoreKind::Dam)]
    {
        for &n in &ns {
            let us = step_time_us(kind, n, t_steps, reps);
            stable.row(vec![label.to_string(), n.to_string(), fmt_time(us / 1e6)]);
            sjson.push(Json::obj(vec![
                ("core", Json::str(label)),
                ("n", Json::num(n as f64)),
                ("us_per_step", Json::num(us)),
            ]));
        }
    }
    stable.print();
    save_bench_root(
        "step",
        Json::obj(vec![
            ("generated_by", Json::str("benches/kernels.rs")),
            ("smoke", Json::Bool(smoke)),
            ("t_steps", Json::num(t_steps as f64)),
            ("steps", Json::arr(sjson)),
        ]),
    );
}
