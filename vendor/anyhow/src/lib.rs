//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build image has no crates.io access, so the crate ships this small
//! vendored stand-in providing exactly the surface the repo uses:
//!
//! * [`Error`] — an opaque error value holding a context chain
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default error
//! * [`anyhow!`] — construct an [`Error`] from a format string or a value
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`/`Option`
//!
//! Formatting matches upstream closely enough for logs and tests:
//! `{e}` prints the outermost context, `{e:#}` prints the whole chain
//! separated by `": "`.

use std::fmt;

/// Opaque error: a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message (what `{}` prints).
    pub fn root_context(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket From possible.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error of a `Result` or to a `None`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { chain: vec![context.to_string(), e.to_string()] })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { chain: vec![f().to_string(), e.to_string()] })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (with inline captures) or
/// from any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| format!("reading {}", "x.bin"))
            .unwrap_err();
        assert_eq!(e.to_string(), "reading x.bin");
        assert_eq!(format!("{e:#}"), "reading x.bin: missing thing");
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("got {n} items from {}", "src");
        assert_eq!(b.to_string(), "got 3 items from src");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing thing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(e.to_string(), "nothing here");
        assert_eq!(Some(5u32).context("unused").unwrap(), 5);
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 7");
    }
}
